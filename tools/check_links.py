"""Markdown link checker — stdlib only, no network.

Scans README.md and docs/*.md for inline links/images and validates the
RELATIVE ones against the working tree: the target file (or directory)
must exist, and a ``#fragment`` into a markdown file must match one of
its headings (GitHub anchor slugs).  External (http/https/mailto) links
are skipped — CI must not flake on the internet.

Usage: python tools/check_links.py [file-or-dir ...]
Exits nonzero listing every broken link (path:line: target).
"""

import pathlib
import re
import sys

# Inline [text](target) and ![alt](target); ignores ```code fences``` below.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^\s*(```|~~~)")


def _anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (lowercase, spaces to dashes)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(md_path: pathlib.Path) -> set:
    out = set()
    fenced = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if _FENCE.match(line):
            fenced = not fenced
        elif not fenced and line.startswith("#"):
            out.add(_anchor(line.lstrip("#")))
    return out


def check_file(path: pathlib.Path) -> list:
    """Return (line_no, target, reason) tuples for broken relative links."""
    errors = []
    fenced = False
    for i, line in enumerate(path.read_text(encoding="utf-8").splitlines(),
                             start=1):
        if _FENCE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, frag = target.partition("#")
            if not base:            # same-file #fragment
                if frag and _anchor(frag) not in _anchors(path):
                    errors.append((i, target, "missing anchor"))
                continue
            dest = (path.parent / base).resolve()
            if not dest.exists():
                errors.append((i, target, "missing file"))
                continue
            if frag and dest.suffix == ".md":
                if _anchor(frag) not in _anchors(dest):
                    errors.append((i, target, "missing anchor"))
    return errors


def main(argv) -> int:
    """Check the given files/dirs (default: README.md + docs/)."""
    root = pathlib.Path(__file__).resolve().parent.parent
    args = [pathlib.Path(a) for a in argv] or [root / "README.md",
                                               root / "docs"]
    files = []
    for a in args:
        files.extend(sorted(a.rglob("*.md")) if a.is_dir() else [a])
    broken = 0
    for f in files:
        for line, target, reason in check_file(f):
            print(f"{f.relative_to(root)}:{line}: {reason}: {target}")
            broken += 1
    print(f"[check_links] {len(files)} files, {broken} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
