# Developer entry points.  The repo is import-run via PYTHONPATH=src (no
# install step); every target bakes that in so CI/tier-1 is one invocation.
#
# Test lanes (mirrored by .github/workflows/ci.yml):
#   test-fast  — tier-1 gate: the bench-smoke serving regression check, then
#                every test OUTSIDE the @pytest.mark.slow marker.  This is
#                the required CI job.
#   test-slow  — ONLY the @slow suite (distributed dry-runs, train-driver
#                end-to-end); runs as a separate non-blocking CI job.
#   test       — the full suite (fast + slow) in one pytest invocation.
#   lint       — ruff over src/ (config in pyproject.toml: E/F/W + import
#                order, line length 88).  Skips with a notice when ruff is
#                not installed locally; CI always installs it
#                (requirements-ci.txt) so the gate is real there.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: lint check-links test-fast test test-slow test-dist test-faults test-overload test-fleet test-async bench bench-smoke bench-serving bench-faults bench-overload bench-fleet bench-utilization

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src; \
	else \
		echo "[lint] ruff not installed; skipping (CI installs it via requirements-ci.txt)"; \
	fi

# Markdown link check: every relative link in README.md + docs/ must
# resolve in the working tree (stdlib-only, no network; the CI docs job).
check-links:
	$(PY) tools/check_links.py

# Tier-1 fast lane: everything except the @pytest.mark.slow end-to-end runs,
# plus the serving smoke benchmark (asserts chunked prefill is not slower
# than prefill-in-decode at tiny shapes).
test-fast: bench-smoke
	$(PY) -m pytest -q -m "not slow"

# Full suite (slow: distributed dry-runs, train-driver end-to-end).
test:
	$(PY) -m pytest -q

# Only the @slow marker suite (the non-blocking CI job).
test-slow:
	$(PY) -m pytest -q -m slow

# Sharded-serving suite on 8 forced placeholder CPU devices.  The @dist
# tests self-skip below 8 devices, so the plain test-fast lane passes them
# by; CI's second required leg runs the WHOLE fast lane under these flags
# (make test-fast with XLA_FLAGS set), which includes this suite.
test-dist:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		$(PY) -m pytest -q -m dist

bench:
	$(PY) benchmarks/run.py

# Tiny-shape serving benchmark gate (float mode, prompt_len 48): fails if
# the chunked prefill path regresses below the legacy tick-per-token path.
# Writes a machine-readable verdict (pass/fail + measured ratio) to
# BENCH_serving_smoke.json, which CI uploads as an artifact.
bench-smoke:
	$(PY) benchmarks/bench_serving.py --smoke

# Full serving benchmark -> BENCH_serving.json (closed-loop TTFT + the
# open-loop load sweep: p50/p99 TTFT and goodput per quant mode).
bench-serving:
	$(PY) benchmarks/bench_serving.py

# Goodput-under-fault-rate sweep (abfp-packed, simulated clock, seeded
# fault traces) -> BENCH_serving_faults.json.  Exits nonzero unless
# recovery-on beats recovery-off at every rate — the CI fault gate.
bench-faults:
	$(PY) benchmarks/bench_serving.py --faults-only

# Fault-injection / recovery suite (includes the @dist mesh-reshard cases
# on 8 forced placeholder CPU devices).
test-faults:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		$(PY) -m pytest -q -m fault

# Overload-robustness suite: paged-pool preemption/resume, admission
# backpressure, degraded modes, tenant quotas (tests/test_pages.py +
# the randomized overload-trace property test).
test-overload:
	$(PY) -m pytest -q -m overload

# Capacity gate (paged vs unpaged max-concurrency at a fixed KV budget)
# + overload sweep (1.2-2.0x service rate; paged+preemption goodput must
# beat the unpaged baseline at every point) -> BENCH_serving_overload.json.
bench-overload:
	$(PY) benchmarks/bench_serving.py --overload-only

# Heterogeneous-fleet suite: ModelRunner families (decoder / recurrent /
# enc-dec), multi-model multiplexed serving, per-model conservation.
test-fleet:
	$(PY) -m pytest -q -m fleet

# Fleet bench + per-arch serving-path quality grid (ABFP logits vs float
# inside the envelope) -> BENCH_serving_fleet.json.  Exits nonzero on a
# per-model conservation failure or a quality miss — the CI fleet gate.
bench-fleet:
	$(PY) benchmarks/bench_serving.py --fleet-only

# Overlapped async-serving suite: wall-clock dispatch pipeline parity
# (overlapped == simulated-clock, bit for bit), DeviceStream seam, and the
# three tick-loop sync-bug regressions.  Timing-assertion-free (fake
# clocks only) so it passes on loaded CI hosts.
test-async:
	$(PY) -m pytest -q -m async

# Overlapped-vs-blocking utilization smoke gate: measures tick utilization
# (device-busy / engine-active wall time) for both dispatch policies at
# open-loop load 0.9 on THIS host -> BENCH_serving_utilization.json.
# Exits nonzero if overlap fails to beat blocking — the CI async gate.
bench-utilization:
	$(PY) benchmarks/bench_serving.py --utilization-gate
