# Developer entry points.  The repo is import-run via PYTHONPATH=src (no
# install step); every target bakes that in so CI/tier-1 is one invocation.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test-fast test bench bench-smoke bench-serving

# Tier-1 fast lane: everything except the @pytest.mark.slow end-to-end runs,
# plus the serving smoke benchmark (asserts chunked prefill is not slower
# than prefill-in-decode at tiny shapes).
test-fast: bench-smoke
	$(PY) -m pytest -q -m "not slow"

# Full suite (slow: distributed dry-runs, train-driver end-to-end).
test:
	$(PY) -m pytest -q

bench:
	$(PY) benchmarks/run.py

# Tiny-shape serving benchmark gate (float mode, prompt_len 48): fails if
# the chunked prefill path regresses below the legacy tick-per-token path.
bench-smoke:
	$(PY) benchmarks/bench_serving.py --smoke

# Full serving benchmark -> BENCH_serving.json (TTFT + tok/s, all modes).
bench-serving:
	$(PY) benchmarks/bench_serving.py
