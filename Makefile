# Developer entry points.  The repo is import-run via PYTHONPATH=src (no
# install step); every target bakes that in so CI/tier-1 is one invocation.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test-fast test bench

# Tier-1 fast lane: everything except the @pytest.mark.slow end-to-end runs.
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Full suite (slow: distributed dry-runs, train-driver end-to-end).
test:
	$(PY) -m pytest -q

bench:
	$(PY) benchmarks/run.py
