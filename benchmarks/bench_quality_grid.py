"""Table II analog — DNN quality over tile width x gain x bitwidths.

The paper's MLPerf models/datasets are not available in this container, so
the grid is reproduced as a *trend benchmark* on a model we train ourselves:
a reduced llama-family LM trained on the synthetic Markov task (repro.data),
then evaluated in ABFP simulation over the same grid the paper sweeps:
tiles {8, 32, 128} x gains {1, 2, 4, 8, 16} x bitwidths {6/6/8, 8/8/8}.

Quality metric = next-token accuracy as % of the FLOAT32 accuracy (the
paper's "percent of FLOAT32 quality").  Checks the structure of Table II:
  * tile 8 / gain 1 retains >99% of FLOAT quality
  * tile 8 degrades as gain rises (saturation)
  * tile 128 / moderate-high gain beats tile 128 / gain 1
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.data import DataConfig, batch_at_step
from repro.models import forward, init_params
from repro.models.layers import Numerics
from repro.optim import AdamW, constant
from repro.training.train_lib import TrainConfig, make_train_step

TILES = (8, 32, 128)
GAINS = (1.0, 2.0, 4.0, 8.0, 16.0)
# Full grid at 8/8/8 (the paper's main setting); 6/6/8 at tile 8 only — the
# paper's finding is that 6-bit operands barely differ, checked there.
BITS = ((6, 6, 8), (8, 8, 8))

TRAIN_STEPS = 200
EVAL_BATCHES = 2


def train_small_lm(seed: int = 0):
    mcfg = dataclasses.replace(
        smoke_config("smollm-360m"), num_layers=4, vocab_size=256)
    dcfg = DataConfig(vocab_size=mcfg.vocab_size, seq_len=64, global_batch=16,
                      seed=seed)
    params = init_params(jax.random.PRNGKey(seed), mcfg)
    opt = AdamW(schedule=constant(3e-3))
    init_state, train_step = make_train_step(mcfg, opt, TrainConfig())
    state = init_state(params)
    step_jit = jax.jit(train_step)
    for i in range(TRAIN_STEPS):
        batch = batch_at_step(dcfg, i)
        state, metrics = step_jit(state, batch,
                                  jax.random.fold_in(jax.random.PRNGKey(1), i))
    return state.params, mcfg, dcfg, float(metrics["loss"])


def accuracy(params, mcfg, dcfg, quant: QuantConfig, key) -> float:
    correct = total = 0
    for i in range(EVAL_BATCHES):
        batch = batch_at_step(dcfg, 10_000 + i)
        tokens = batch["tokens"]
        nx = Numerics(quant, jax.random.fold_in(key, i))
        logits, _ = forward(params, tokens[:, :-1], mcfg, nx)
        pred = jnp.argmax(logits, axis=-1)
        correct += int((pred == tokens[:, 1:]).sum())
        total += tokens[:, 1:].size
    return correct / total


def run(csv_rows: list) -> dict:
    t0 = time.time()
    params, mcfg, dcfg, final_loss = train_small_lm()
    float_acc = accuracy(params, mcfg, dcfg, QuantConfig(mode="float"),
                         jax.random.PRNGKey(2))
    csv_rows.append(f"quality_float32,{(time.time()-t0)*1e6:.0f},"
                    f"acc={float_acc:.4f}")
    assert float_acc > 0.30, f"model failed to learn (acc={float_acc})"

    grid = {}
    for bw, bx, by in BITS:
        for tile in TILES:
            if (bw, bx, by) == (6, 6, 8) and tile != 8:
                continue
            for gain in GAINS:
                qc = QuantConfig(mode="abfp_ref", tile_width=tile, gain=gain,
                                 bits_w=bw, bits_x=bx, bits_y=by,
                                 noise_lsb=0.5)
                t1 = time.time()
                acc = accuracy(params, mcfg, dcfg, qc, jax.random.PRNGKey(3))
                rel = 100.0 * acc / float_acc
                grid[(f"{bw}/{bx}/{by}", tile, gain)] = rel
                csv_rows.append(
                    f"quality_{bw}{bx}{by}_t{tile}_g{int(gain)},"
                    f"{(time.time()-t1)*1e6:.0f},pct_float={rel:.1f}")

    checks = {
        "tile8_g1_above_99pct": grid[("8/8/8", 8, 1.0)] > 99.0,
        "tile8_degrades_with_gain":
            grid[("8/8/8", 8, 16.0)] < grid[("8/8/8", 8, 1.0)],
        "tile128_gain_helps":
            max(grid[("8/8/8", 128, g)] for g in (4.0, 8.0, 16.0))
            > grid[("8/8/8", 128, 1.0)],
        "bitwidth_6_vs_8_small_effect":
            abs(grid[("6/6/8", 8, 1.0)] - grid[("8/8/8", 8, 1.0)]) < 5.0,
    }
    assert all(checks.values()), (checks, grid)
    return {"float_acc": float_acc, "final_loss": final_loss,
            "grid": {str(k): v for k, v in grid.items()}, "checks": checks}


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
    print("checks:", out["checks"])
