"""Serving benchmark: closed-loop TTFT (prefill-in-decode vs chunked
prefill) plus an OPEN-LOOP load sweep with per-request SLO metrics, across
numerics modes (float / abfp-kernel / abfp-packed).

Closed loop: each (mode, chunked) cell builds a fresh engine, runs a small
warmup workload that touches every jit shape the timed run needs (decode
tick + each prefill bucket), then times one full workload: TTFT is wall
time from first admission until EVERY request has its first token
(requests == capacity, all admitted at once); throughput is generated
tokens over the full run.

Open loop: the engine runs on the WALL clock (``clock=time.perf_counter``)
and requests arrive by a Poisson process whose rate is a ``--loads``
multiple of the calibrated closed-loop service rate.  Reported per cell:
p50/p99 TTFT, p50 TPOT, and goodput (requests finishing within the TTFT
SLO per second; the SLO is 3x the calibrated per-request p50 TTFT).
Full runs measure each (mode, load) cell twice — BLOCKING dispatch
(fetch-per-tick) and the OVERLAPPED pipeline (on-device sampling,
background delivery, dispatch-ahead) — and record ``tick_utilization``
(device-busy over engine-active wall time) for both.
``--utilization-gate`` runs only the blocking-vs-overlapped comparison at
load 0.9 and writes BENCH_serving_utilization.json (the CI async gate:
overlap must not utilize the device less than blocking).

    PYTHONPATH=src python benchmarks/bench_serving.py         # BENCH_serving.json
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke # tiny shapes; writes
                                                              # pass/fail + ratio to
                                                              # BENCH_serving_smoke.json

The smoke gate (`make bench-smoke`, part of `make test-fast` and CI) fails
when chunked prefill is slower than prefill-in-decode; its JSON artifact
records the measured ratio either way so CI shows the number when the gate
trips.

Full (non-smoke) runs also sweep sharded serving over mesh shapes
(dp, tp) in {(1,1), (2,1), (1,2), (2,4)} on forced placeholder CPU
devices — one subprocess per shape, since the XLA device-count flag binds
at first jax use — and record per-shape closed-loop rows under
``mesh_sweep`` in BENCH_serving.json (``--no-mesh-sweep`` skips).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.models import init_params, param_count
from repro.serving import FaultConfig, Request, ServingEngine


def _quant(mode: str) -> QuantConfig:
    if mode == "float":
        return QuantConfig(mode="float")
    jmode = {"abfp-kernel": "abfp_kernel", "abfp-packed": "abfp_packed"}[mode]
    return QuantConfig(mode=jmode, tile_width=32, gain=8.0, noise_lsb=0.5)


def _workload(mcfg, n, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, mcfg.vocab_size,
                                        prompt_len).tolist(),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run(eng, reqs):
    """Admit everything, serve to completion.  Returns (ttft_s, total_s,
    generated_tokens, ticks)."""
    ticks0 = eng.ticks
    t0 = time.perf_counter()
    for r in reqs:
        assert eng.try_admit(r), "workload must fit capacity"
    ttft = None
    while any(s is not None for s in eng.slots):
        eng.step()
        if ttft is None and all(r.generated for r in reqs):
            ttft = time.perf_counter() - t0
    total = time.perf_counter() - t0
    return ttft, total, sum(len(r.generated) for r in reqs), eng.ticks - ticks0


def _warm(eng, mcfg, *, chunked, chunks, capacity, max_len):
    """Compile every shape a timed run could hit: the decode tick and
    (chunked only) each prefill bucket."""
    warm_lens = ({min(c, max_len - 2) for c in chunks} if chunked else {2})
    for warm_prompt in sorted(warm_lens):
        _run(eng, _workload(mcfg, min(2, capacity), warm_prompt, 2, seed=99))


def bench_cell(params, mcfg, *, mode, chunked, capacity, prompt_len,
               max_new, max_len, chunks, seed, mesh=None):
    eng = ServingEngine(params, mcfg, capacity=capacity, max_len=max_len,
                        quant=_quant(mode), seed=seed, chunked=chunked,
                        prefill_chunks=chunks, mesh=mesh)
    # Warm prompts are capped at max_len - 2 (admission guard); the cap
    # selects the same bucket as the largest admissible timed prompt, so
    # every reachable bucket still gets warmed.
    _warm(eng, mcfg, chunked=chunked, chunks=chunks, capacity=capacity,
          max_len=max_len)
    ttft, total, toks, ticks = _run(
        eng, _workload(mcfg, capacity, prompt_len, max_new, seed=seed))
    return {"mode": mode, "chunked": chunked, "ttft_s": round(ttft, 4),
            "total_s": round(total, 4), "tok_per_s": round(toks / total, 2),
            "ticks": ticks}


def calibrate_open_loop(params, mcfg, *, mode, capacity, prompt_len,
                        max_new, max_len, chunks, seed, slo_scale=3.0):
    """Closed-loop calibration on a BLOCKING wall-clock engine: service
    rate (req/s at full occupancy) and the TTFT SLO every open-loop cell
    of this mode is judged against.  Shared between the blocking and the
    overlapped cells so their SLOs (and arrival processes) are identical."""
    eng = ServingEngine(params, mcfg, capacity=capacity, max_len=max_len,
                        quant=_quant(mode), seed=seed, chunked=True,
                        prefill_chunks=chunks, policy="fcfs",
                        clock=time.perf_counter)
    _warm(eng, mcfg, chunked=True, chunks=chunks, capacity=capacity,
          max_len=max_len)
    eng.metrics.reset()
    _, total_s, _, _ = _run(
        eng, _workload(mcfg, capacity, prompt_len, max_new, seed=seed + 1))
    service_rps = capacity / total_s
    slo_ttft = slo_scale * eng.metrics.summary()["ttft"]["p50"]
    return {"service_rps": service_rps, "slo_ttft": slo_ttft}


def bench_open_loop(params, mcfg, *, mode, load, capacity, prompt_len,
                    max_new, max_len, chunks, seed, n_requests,
                    slo_scale=3.0, overlap=False, calib=None):
    """One open-loop cell: wall-clock engine, Poisson arrivals at ``load``
    x the calibrated service rate, FCFS admission.  ``overlap=True`` runs
    the same cell through the overlapped dispatch pipeline (on-device
    sampling, background delivery); the row then also reports tick
    utilization (device-busy over engine-active wall time)."""
    if calib is None:
        calib = calibrate_open_loop(
            params, mcfg, mode=mode, capacity=capacity,
            prompt_len=prompt_len, max_new=max_new, max_len=max_len,
            chunks=chunks, seed=seed, slo_scale=slo_scale)
    slo_ttft = calib["slo_ttft"]

    eng = ServingEngine(params, mcfg, capacity=capacity, max_len=max_len,
                        quant=_quant(mode), seed=seed, chunked=True,
                        prefill_chunks=chunks, policy="fcfs",
                        clock=time.perf_counter, overlap=overlap)
    # AOT-compile the decode tick + every prefill bucket, then run a small
    # warm workload to compile the per-admission jits (slot reset/attach)
    # too — no compile may land inside the timed window.  The warm pass
    # also pays the first-dispatch overhead per shape, so both cells start
    # steady-state; metrics (incl. the utilization gauges) reset after.
    eng.warmup()
    _warm(eng, mcfg, chunked=True, chunks=chunks, capacity=capacity,
          max_len=max_len)
    eng.sync()
    eng._drain_delivered()
    eng.metrics.reset()

    rate = load * calib["service_rps"]
    rng = np.random.default_rng(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    t0 = time.perf_counter()
    for i, off in enumerate(offsets):
        eng.submit(Request(uid=10_000 + i,
                           prompt=rng.integers(
                               1, mcfg.vocab_size, prompt_len).tolist(),
                           max_new_tokens=max_new,
                           arrival_time=t0 + float(off)))
    done = eng.drain()
    duration = time.perf_counter() - t0
    s = eng.metrics.summary()
    good = eng.metrics.goodput(slo_ttft, duration=duration)
    eng.close()

    def _round(v, nd=4):
        return None if v is None else round(v, nd)

    tu = s["tick_utilization"]
    return {"mode": mode, "load": load, "overlap": overlap,
            "arrival_rate_rps": round(rate, 2),
            "ttft_p50_s": _round(s["ttft"]["p50"]),
            "ttft_p99_s": _round(s["ttft"]["p99"]),
            "tpot_p50_s": _round(s["tpot"]["p50"]),   # None when max_new==1
            "slo_ttft_s": round(slo_ttft, 4),
            "goodput_rps": _round(good, 2),
            "finished": len(done),
            "max_queue_depth": s["queue_depth"]["max"],
            "tick_utilization": _round(tu["value"]),
            "device_busy_s": _round(tu["device_busy_s"]),
            "active_s": _round(tu["active_s"])}


# ---------------------------------------------------------------------------
# Goodput under fault injection: rate sweep, recovery on vs off
# ---------------------------------------------------------------------------

FAULT_RATES = (0.001, 0.01, 0.05)

# Stamped into every BENCH json this script writes; bump when row fields
# change shape so downstream tooling can dispatch on it.
SCHEMA_VERSION = 3


# ---------------------------------------------------------------------------
# Overlapped-dispatch utilization gate: overlap must not idle the device
# more than blocking does on the same host at the same load
# ---------------------------------------------------------------------------

def bench_utilization_gate(params, mcfg, *, seed, load=0.9,
                           prompt_len=48, capacity=4, max_new=8,
                           max_len=128, chunks=(8, 16), n_requests=16):
    """Blocking vs overlapped open-loop cells at the SAME load on the SAME
    host, sharing one calibration (identical arrival process + SLO).  The
    gate passes when the overlapped pipeline's tick utilization is at
    least the blocking engine's (small epsilon for run-to-run jitter):
    dispatching ahead must never leave the device MORE host-starved than
    synchronous fetch-per-tick does."""
    cell = dict(mode="float", capacity=capacity, prompt_len=prompt_len,
                max_new=max_new, max_len=max_len, chunks=chunks, seed=seed)
    calib = calibrate_open_loop(params, mcfg, **cell)
    blocking = bench_open_loop(params, mcfg, load=load, overlap=False,
                               calib=calib, n_requests=n_requests, **cell)
    overlapped = bench_open_loop(params, mcfg, load=load, overlap=True,
                                 calib=calib, n_requests=n_requests, **cell)
    b, o = blocking["tick_utilization"], overlapped["tick_utilization"]
    ok = (b is not None and o is not None and o >= b - 0.02)
    return {"load": load, "blocking": blocking, "overlapped": overlapped,
            "pass": bool(ok)}


def bench_fault_sweep(params, mcfg, *, mode, seed,
                      rates=FAULT_RATES, n_requests=24) -> list:
    """Open-loop goodput vs per-tick fault rate, recovery on vs off.

    Runs on the SIMULATED clock (deterministic: same seeds -> same fault
    trace and the same arrivals for every cell), small shapes — this
    measures robustness accounting, not kernel throughput.  Goodput
    excludes corrupted requests (tokens computed against unrepaired
    faulted weights); ``degraded_goodput`` counts them anyway.  Every cell
    asserts request conservation after drain."""
    capacity, prompt_len, max_new, max_len = 4, 8, 8, 64
    chunks = (4, 8)

    def _arrivals(rng):
        return np.cumsum(rng.exponential(1.0, n_requests))

    # Fault-free calibration fixes the TTFT SLO for every cell.
    eng = ServingEngine(params, mcfg, capacity=capacity, max_len=max_len,
                        quant=_quant(mode), seed=seed, chunked=True,
                        prefill_chunks=chunks)
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rng)
    for i, at in enumerate(arrivals):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(
                               1, mcfg.vocab_size, prompt_len).tolist(),
                           max_new_tokens=max_new, arrival_time=float(at)))
    eng.drain()
    calib = eng.metrics.summary()
    slo_ttft = 3.0 * calib["ttft"]["p50"]

    rows = []
    for rate in rates:
        for recovery in (True, False):
            eng = ServingEngine(
                params, mcfg, capacity=capacity, max_len=max_len,
                quant=_quant(mode), seed=seed, chunked=True,
                prefill_chunks=chunks,
                # horizon ~ the trace length so the >=1-event floor lands
                # inside the run even at the 0.1% rate.
                faults=FaultConfig(rate=rate, seed=seed + 17, horizon=48),
                recovery=recovery, detect_every=2)
            rng = np.random.default_rng(seed)
            arrivals = _arrivals(rng)
            for i, at in enumerate(arrivals):
                eng.submit(Request(
                    uid=i,
                    prompt=rng.integers(
                        1, mcfg.vocab_size, prompt_len).tolist(),
                    max_new_tokens=max_new, arrival_time=float(at)))
            eng.drain()
            cons = eng.metrics.conservation()
            assert cons["ok"], (rate, recovery, cons)
            s = eng.metrics.summary()
            good = eng.metrics.goodput(slo_ttft)
            degraded = eng.metrics.goodput(slo_ttft,
                                           include_corrupted=True)
            rows.append({
                "mode": mode, "fault_rate": rate, "recovery": recovery,
                "slo_ttft": round(slo_ttft, 4),
                "goodput_per_tick": None if good is None else round(good, 4),
                "degraded_goodput_per_tick": (
                    None if degraded is None else round(degraded, 4)),
                "injected": s["faults"]["injected"],
                "detected": s["faults"]["detected"],
                "cols_remapped": s["faults"]["cols_remapped"],
                "tiles_requantized": s["faults"]["tiles_requantized"],
                "reshards": s["faults"]["reshards"],
                "corrupted": s["requests"]["corrupted"],
                "requeued": s["requests"]["requeued"],
                "timed_out": s["requests"]["timed_out"],
                "conservation_ok": cons["ok"],
                "ticks": s["ticks"],
            })
    return rows


def fault_gate(rows) -> bool:
    """Recovery-on must beat recovery-off on goodput at every rate."""
    by_rate = {}
    for r in rows:
        by_rate.setdefault(r["fault_rate"], {})[r["recovery"]] = (
            r["goodput_per_tick"] or 0.0)
    return all(pair.get(True, 0.0) > pair.get(False, 0.0)
               for pair in by_rate.values())


# ---------------------------------------------------------------------------
# Overload robustness: paged capacity gate + goodput-under-overload sweep
# ---------------------------------------------------------------------------

OVERLOAD_LOADS = (1.2, 1.6, 2.0)


def _drive_trace(eng, reqs):
    """Arrival-driven serve: submit each request only once the simulated
    clock reaches its arrival (so admission backpressure sees true queue
    state), then drain.  Returns the finished list."""
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    finished = []
    while pending or len(eng.scheduler) \
            or any(s is not None for s in eng.slots) or eng._returned:
        while pending and pending[0].arrival_time <= eng.now:
            r = pending.pop(0)
            if not eng.submit(r):
                finished.append(r)
        got = eng.poll()
        finished.extend(got)
        if not got and pending and not len(eng.scheduler) \
                and all(s is None for s in eng.slots):
            eng.now = pending[0].arrival_time    # idle: jump to next arrival
    return finished


def bench_capacity_gate(params, mcfg, *, seed) -> dict:
    """Max concurrent requests at a FIXED KV budget of 256 token-slots:
    unpaged spends it as 4 slots x max_len 64; paged spends the same 256
    tokens as a 16-page x 16-token pool shared by 12 slots, so short
    requests (~1 page each) stack 3x deeper.  Simulated clock; the gate is
    STRICT (paged > unpaged)."""
    n, prompt_len, max_new = 16, 8, 4

    def _measure(**ekw):
        eng = ServingEngine(params, mcfg, quant=_quant("float"), seed=seed,
                            chunked=True, prefill_chunks=(4, 8), **ekw)
        reqs = _workload(mcfg, n, prompt_len, max_new, seed=seed)
        for r in reqs:
            r.arrival_time = 0.0
        peak = 0
        for r in reqs:
            eng.submit(r)
        while len(eng.scheduler) or any(s is not None for s in eng.slots):
            eng.poll()
            peak = max(peak, sum(s is not None for s in eng.slots))
        cons = eng.metrics.conservation()
        assert cons["ok"], cons
        return peak, eng.ticks

    unpaged_peak, unpaged_ticks = _measure(capacity=4, max_len=64)
    paged_peak, paged_ticks = _measure(capacity=12, max_len=64, paged=True,
                                       page_size=16, pool_pages=16)
    return {"kv_budget_tokens": 256, "prompt_len": prompt_len,
            "max_new": max_new, "n_requests": n,
            "unpaged": {"capacity": 4, "max_concurrent": unpaged_peak,
                        "ticks": unpaged_ticks},
            "paged": {"capacity": 12, "page_size": 16, "pool_pages": 16,
                      "max_concurrent": paged_peak, "ticks": paged_ticks},
            "pass": bool(paged_peak > unpaged_peak)}


def bench_overload_sweep(params, mcfg, *, seed, loads=OVERLOAD_LOADS,
                         n_requests=32) -> list:
    """Goodput at 1.2-2.0x the calibrated service rate, robust (paged +
    preemption + admission watermarks, 12 slots on the same 256-token KV
    budget) vs the unpaged shed-nothing seed engine (4 slots).  Simulated
    clock, deterministic arrivals per seed; TTFT SLO fixed by a fault-free
    closed-loop calibration of the SEED engine.  Every cell asserts
    request conservation (extended with preemption accounting)."""
    # 20-token requests (2 pages of 16): 12 robust slots want up to 24
    # pages against a 16-page pool, so page pressure and preemption are
    # actually exercised at the high load points.
    prompt_len, max_new, max_len = 8, 12, 64
    chunks = (4, 8)
    base_kw = dict(quant=_quant("float"), seed=seed, chunked=True,
                   prefill_chunks=chunks, max_len=max_len)

    # Calibrate the seed engine closed-loop: service rate in req/tick and
    # the TTFT SLO (3x unloaded p50) every cell is judged against.
    eng = ServingEngine(params, mcfg, capacity=4, **base_kw)
    reqs = _workload(mcfg, 8, prompt_len, max_new, seed=seed + 1)
    for r in reqs:
        r.arrival_time = 0.0
    t0 = eng.ticks
    eng.run(reqs)
    service_rate = 8 / max(1, eng.ticks - t0)       # req per tick
    slo_ttft = 3.0 * eng.metrics.summary()["ttft"]["p50"]

    rows = []
    for load in loads:
        rate = load * service_rate
        for robust in (False, True):
            if robust:
                eng = ServingEngine(params, mcfg, capacity=12, paged=True,
                                    page_size=16, pool_pages=16,
                                    queue_watermark=3 * 12, **base_kw)
            else:
                eng = ServingEngine(params, mcfg, capacity=4, **base_kw)
            rng = np.random.default_rng(seed + int(load * 100))
            arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
            reqs = [Request(uid=i,
                            prompt=rng.integers(1, mcfg.vocab_size,
                                                prompt_len).tolist(),
                            max_new_tokens=max_new,
                            arrival_time=float(arrivals[i]))
                    for i in range(n_requests)]
            _drive_trace(eng, reqs)
            cons = eng.metrics.conservation()
            assert cons["ok"] and cons["preempt_ok"], (load, robust, cons)
            s = eng.metrics.summary()
            good = eng.metrics.goodput(slo_ttft)
            rows.append({
                "load": load, "robust": robust,
                "arrival_rate_per_tick": round(rate, 4),
                "slo_ttft_ticks": round(slo_ttft, 2),
                "goodput_per_tick": None if good is None else round(good, 4),
                "finished": s["requests"]["finished"],
                "shed": s["requests"]["shed"],
                "preempted": s["requests"]["preempted"],
                "resumed": s["requests"]["resumed"],
                "ttft_p50": (None if s["ttft"]["p50"] is None
                             else round(s["ttft"]["p50"], 2)),
                "max_queue_depth": s["queue_depth"]["max"],
                "conservation_ok": cons["ok"],
                "ticks": s["ticks"],
            })
    return rows


def overload_gate(rows) -> bool:
    """Robust (paged+preemption+backpressure) goodput must be >= the
    shed-nothing seed at EVERY load point."""
    by_load = {}
    for r in rows:
        by_load.setdefault(r["load"], {})[r["robust"]] = (
            r["goodput_per_tick"] or 0.0)
    return all(pair.get(True, 0.0) >= pair.get(False, 0.0)
               for pair in by_load.values())


# ---------------------------------------------------------------------------
# Heterogeneous fleet: multiplexed multi-model serving + per-arch quality grid
# ---------------------------------------------------------------------------

FLEET_ARCHS = ("whisper-base", "recurrentgemma-2b", "xlstm-350m")

# Serving-path numerics envelope: ABFP(+read noise) logits on the runner
# prefill/decode path must track float within this normalized error
# (median |l_q - l_f| over the float logit std).  Top-1 agreement is
# recorded but NOT gated: smoke models are untrained, so near-uniform
# logits make argmax flips noise, not signal.
FLEET_QUALITY_ENVELOPE = 0.35


def _fleet_models(archs, seed) -> dict:
    models = {}
    for i, a in enumerate(archs):
        cfg = smoke_config(a)
        models[a] = (init_params(jax.random.PRNGKey(seed + i), cfg), cfg)
    return models


def _fleet_features(runner, seed, uid):
    from repro.models import frontends
    key = jax.random.fold_in(jax.random.PRNGKey(seed), uid)
    return np.asarray(
        frontends.audio_stub_features(
            key, 1, runner.enc_len, runner.mcfg.d_model)[0], np.float32)


def _fleet_workload(models, runners, *, n_per_model, prompt_len, max_new,
                    seed) -> list:
    """Round-robin across models so every tick interleaves lanes; enc-dec
    requests carry per-request stub frontend features."""
    rng = np.random.default_rng(seed)
    names = list(models)
    reqs = []
    for i in range(n_per_model * len(names)):
        name = names[i % len(names)]
        mcfg = models[name][1]
        r = Request(uid=i,
                    prompt=rng.integers(1, mcfg.vocab_size,
                                        prompt_len).tolist(),
                    max_new_tokens=max_new, model=name)
        if runners[name].needs_admission:
            r.features = _fleet_features(runners[name], seed, i)
        reqs.append(r)
    return reqs


def bench_fleet(models, *, mode, seed, n_per_model=4, prompt_len=8,
                max_new=4, max_len=64, capacity_per_model=2) -> dict:
    """Multiplexed fleet vs sequential per-model serving of the SAME
    workload.  Multiplexed: one FleetEngine, shared clock, round-robin
    lanes.  Sequential: one single-model engine per arch, run back to
    back.  Reports per-arch TTFT/TPOT through the fleet lanes plus the
    tick and wall-throughput comparison; asserts per-model request
    conservation on the multiplexed run."""
    from repro.serving.runners import runner_for

    names = list(models)
    runners = {n: runner_for(cfg) for n, (_, cfg) in models.items()}
    chunks = (4, 8)

    fleet = ServingEngine(
        models={n: (models[n][0], models[n][1], runners[n]) for n in names},
        capacity=capacity_per_model * len(names), max_len=max_len,
        quant=_quant(mode), seed=seed, chunked=True, prefill_chunks=chunks)
    reqs = _fleet_workload(models, runners, n_per_model=n_per_model,
                           prompt_len=prompt_len, max_new=max_new, seed=seed)
    t0 = time.perf_counter()
    done = fleet.run(reqs)
    mux_wall = time.perf_counter() - t0
    mux_ticks = fleet.ticks
    mux_tokens = sum(len(r.generated) for r in done)
    cons = fleet.conservation()
    summaries = fleet.summary()

    per_arch = []
    for n in names:
        s, c = summaries[n], cons[n]
        def _r(v):
            return None if v is None else round(float(v), 4)

        per_arch.append({
            "arch": n, "runner": type(runners[n]).__name__,
            "slots": fleet.lanes[n].capacity,
            "ttft_p50": _r(s["ttft"]["p50"]),
            "ttft_p99": _r(s["ttft"]["p99"]),
            "tpot_p50": _r(s["tpot"]["p50"]),
            "completed": c["completed"], "submitted": c["submitted"],
            "preempted": c["preempted"],
            "conservation_ok": bool(c["ok"])})

    # Sequential baseline: same per-model workload through isolated
    # single-model engines, one after another.
    seq_wall, seq_ticks, seq_tokens = 0.0, 0, 0
    for n in names:
        eng = ServingEngine(models[n][0], models[n][1], runner=runners[n],
                            capacity=capacity_per_model, max_len=max_len,
                            quant=_quant(mode), seed=seed, chunked=True,
                            prefill_chunks=chunks)
        sub = [r for r in _fleet_workload(
            models, runners, n_per_model=n_per_model, prompt_len=prompt_len,
            max_new=max_new, seed=seed) if r.model == n]
        t0 = time.perf_counter()
        fin = eng.run(sub)
        seq_wall += time.perf_counter() - t0
        seq_ticks += eng.ticks
        seq_tokens += sum(len(r.generated) for r in fin)

    return {
        "archs": names, "mode": mode, "n_requests": len(reqs),
        "per_arch": per_arch,
        "multiplexed": {"ticks": mux_ticks, "wall_s": round(mux_wall, 3),
                        "tokens": mux_tokens,
                        "tok_per_s": round(mux_tokens / max(mux_wall, 1e-9),
                                           1)},
        "sequential": {"ticks": seq_ticks, "wall_s": round(seq_wall, 3),
                       "tokens": seq_tokens,
                       "tok_per_s": round(seq_tokens / max(seq_wall, 1e-9),
                                          1)},
        "conservation_ok": bool(all(c["ok"] for c in cons.values())),
    }


def fleet_quality_rows(models, *, seed, prompt_len=16,
                       envelope=FLEET_QUALITY_ENVELOPE) -> list:
    """Reduced DNF-style accuracy grid over the SERVING path: for each
    arch, prefill one prompt through the runner's own closures in float
    and in ABFP(+0.5 LSB read noise) and compare last-token logits —
    normalized rel_err must stay inside the envelope.  Also reports the
    per-layer differential-noise stds (core.dnf over forward_capture) so
    regressions point at the offending layer, and top-1 agreement
    (recorded, not gated — see FLEET_QUALITY_ENVELOPE)."""
    import jax.numpy as jnp

    from repro.core.dnf import NoiseHistogram
    from repro.models import forward_capture
    from repro.models.layers import Numerics
    from repro.serving.runners import runner_for

    qa = QuantConfig(mode="abfp_ref", tile_width=32, gain=8.0, noise_lsb=0.5)
    rows = []
    for name, (params, mcfg) in models.items():
        runner = runner_for(mcfg)
        rng = np.random.default_rng(seed)
        prompt = rng.integers(1, mcfg.vocab_size, prompt_len)
        tokens = jnp.asarray(prompt[None])
        n_tok = jnp.full((1,), prompt_len, jnp.int32)
        feats = (_fleet_features(runner, seed, 0)
                 if runner.needs_admission else None)
        akey = jax.random.PRNGKey(seed + 7)

        def last_logits(quant):
            state = runner.init_state(1, 2 * prompt_len)
            if runner.needs_admission:
                state = runner.make_admit(quant, None)(
                    params, state, jnp.asarray(feats), jnp.int32(0), akey)
            logits, _ = jax.jit(runner.make_prefill(quant, None))(
                params, state, tokens, n_tok, jax.random.PRNGKey(seed))
            return np.asarray(logits[0], np.float32)

        lf = last_logits(QuantConfig(mode="float"))
        lq = last_logits(qa)
        rel_err = float(np.median(np.abs(lq - lf)) / max(lf.std(), 1e-9))
        top1 = bool(int(lf.argmax()) == int(lq.argmax()))

        # Per-layer differential noise on the same prompt (paper Fig. 3
        # capture, reused from the DNF pipeline).
        counter = [0]

        def _factory():
            counter[0] += 1
            return Numerics(qa, jax.random.fold_in(
                jax.random.PRNGKey(seed + 13), counter[0]))

        _, deltas = forward_capture(
            params, tokens, mcfg, Numerics(QuantConfig(mode="float"),
                                           jax.random.PRNGKey(seed)),
            _factory,
            encoder_features=(jnp.asarray(feats)[None]
                              if feats is not None else None))
        layer_stds = [round(float(NoiseHistogram.fit(d).std), 6)
                      for d in deltas]

        rows.append({
            "arch": name, "runner": type(runner).__name__,
            "prompt_len": prompt_len, "quant": "abfp_ref t32 g8 n0.5",
            "rel_err": round(rel_err, 4), "envelope": envelope,
            "top1_agree": top1,
            "dnf_layer_std": layer_stds,
            "pass": bool(rel_err <= envelope)})
    return rows


def fleet_gate(fleet_row, quality_rows) -> bool:
    """Per-model conservation on the multiplexed run AND every arch's
    serving-path ABFP logits inside the quality envelope."""
    return bool(fleet_row["conservation_ok"]
                and all(r["completed"] == r["submitted"]
                        for r in fleet_row["per_arch"])
                and all(q["pass"] for q in quality_rows))


# ---------------------------------------------------------------------------
# Per-mesh-shape sweep: sharded serving throughput at forced CPU meshes
# ---------------------------------------------------------------------------

MESH_SHAPES = ((1, 1), (2, 1), (1, 2), (2, 4))


def mesh_one(args) -> None:
    """Child-process entry (--mesh-one dp,tp): one closed-loop cell per mode
    on that mesh, rows printed as ``MESH_ROW <json>`` for the parent.  The
    parent forces dp*tp placeholder CPU devices via XLA_FLAGS before spawn
    (the flag must be set before first jax use, hence the subprocess)."""
    dp, tp = (int(v) for v in args.mesh_one.split(","))
    mesh = jax.make_mesh((dp, tp), ("data", "model"))
    mcfg = smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), mcfg)
    chunks = tuple(int(c) for c in args.chunks.split(","))
    for mode in args.modes.split(","):
        row = bench_cell(params, mcfg, mode=mode, chunked=True,
                         capacity=args.capacity, prompt_len=args.prompt_len,
                         max_new=args.max_new, max_len=args.max_len,
                         chunks=chunks, seed=args.seed, mesh=mesh)
        row["mesh"] = [dp, tp]
        print("MESH_ROW " + json.dumps(row), flush=True)


def mesh_sweep(args) -> list:
    """Spawn one subprocess per mesh shape (XLA device-count forcing is a
    process-level, first-jax-use flag) and collect the MESH_ROW lines."""
    import os
    import subprocess

    rows = []
    for dp, tp in MESH_SHAPES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={dp * tp}").strip()
        cmd = [sys.executable, __file__, "--mesh-one", f"{dp},{tp}",
               "--arch", args.arch, "--modes", "float,abfp-packed",
               "--capacity", "4", "--prompt-len", "8", "--max-new", "4",
               "--max-len", "32", "--chunks", "4,8",
               "--seed", str(args.seed)]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1200)
        got = [json.loads(ln.split(" ", 1)[1])
               for ln in r.stdout.splitlines() if ln.startswith("MESH_ROW ")]
        if r.returncode != 0 or not got:
            print(f"  mesh ({dp},{tp}): FAILED\n{r.stdout}{r.stderr}")
            raise SystemExit(1)
        for row in got:
            print(f"  mesh ({dp},{tp}) {row['mode']:12s} "
                  f"tok/s {row['tok_per_s']:8.1f}  ttft {row['ttft_s']:.3f}s "
                  f"ticks {row['ticks']}")
        rows += got
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=320)
    ap.add_argument("--modes", default="float,abfp-kernel,abfp-packed")
    ap.add_argument("--chunks", default="16,64,128")
    ap.add_argument("--loads", default="0.5,0.9",
                    help="open-loop arrival rates as multiples of the "
                         "calibrated closed-loop service rate")
    ap.add_argument("--open-requests", type=int, default=None,
                    help="requests per open-loop cell (default 2*capacity)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_serving.json at "
                         "the repo root; BENCH_serving_smoke.json with "
                         "--smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, float only; gates on the chunked "
                         "path not being slower than prefill-in-decode and "
                         "writes a machine-readable pass/fail JSON")
    ap.add_argument("--mesh-one", default=None,
                    help="internal (child of the mesh sweep): run one "
                         "closed-loop cell per mode on a dp,tp mesh and "
                         "print MESH_ROW json lines")
    ap.add_argument("--no-mesh-sweep", action="store_true",
                    help="skip the per-mesh-shape sharded-serving sweep "
                         "(full runs only; --smoke never sweeps)")
    ap.add_argument("--faults-only", action="store_true",
                    help="run ONLY the goodput-under-fault-rate sweep and "
                         "write BENCH_serving_faults.json; exits nonzero "
                         "when recovery-on fails to beat recovery-off at "
                         "any rate (the CI fault gate)")
    ap.add_argument("--fault-rates", default=None,
                    help="comma-separated per-tick fault rates for the "
                         "sweep (default 0.001,0.01,0.05)")
    ap.add_argument("--no-fault-sweep", action="store_true",
                    help="skip the fault sweep on full runs")
    ap.add_argument("--overload-only", action="store_true",
                    help="run ONLY the paged capacity gate + the goodput-"
                         "under-overload sweep and write "
                         "BENCH_serving_overload.json; exits nonzero when "
                         "paged does not beat unpaged concurrency at the "
                         "fixed KV budget or robust goodput drops below "
                         "the seed at any load (the CI overload gate)")
    ap.add_argument("--overload-loads", default=None,
                    help="comma-separated overload multiples of the "
                         "calibrated service rate (default 1.2,1.6,2.0)")
    ap.add_argument("--no-overload-sweep", action="store_true",
                    help="skip the capacity gate + overload sweep on "
                         "full runs")
    ap.add_argument("--fleet-only", action="store_true",
                    help="run ONLY the heterogeneous-fleet bench (whisper + "
                         "recurrentgemma + xlstm multiplexed on one engine) "
                         "plus the per-arch serving-path quality grid and "
                         "write BENCH_serving_fleet.json; exits nonzero on "
                         "per-model conservation failure or a quality-"
                         "envelope miss (the CI fleet gate)")
    ap.add_argument("--fleet-archs", default=None,
                    help="comma-separated archs for the fleet bench "
                         "(default whisper-base,recurrentgemma-2b,"
                         "xlstm-350m)")
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the fleet bench + quality grid on full runs")
    ap.add_argument("--utilization-gate", action="store_true",
                    help="run ONLY the blocking-vs-overlapped tick-"
                         "utilization comparison at open-loop load 0.9 and "
                         "write BENCH_serving_utilization.json; exits "
                         "nonzero when the overlapped pipeline utilizes the "
                         "device less than the blocking engine on this "
                         "host (the CI async gate)")
    args = ap.parse_args()

    if args.mesh_one:
        mesh_one(args)
        return

    if args.utilization_gate:
        mcfg = smoke_config(args.arch)
        params = init_params(jax.random.PRNGKey(args.seed), mcfg)
        print("[bench_serving] utilization gate: blocking vs overlapped "
              "at open-loop load 0.9")
        gate = bench_utilization_gate(params, mcfg, seed=args.seed)
        for label in ("blocking", "overlapped"):
            r = gate[label]
            print(f"  {label:10s} tick_utilization {r['tick_utilization']} "
                  f"(device busy {r['device_busy_s']}s of {r['active_s']}s "
                  f"active)  ttft p50 {r['ttft_p50_s']}s  "
                  f"goodput {r['goodput_rps']} req/s")
        out = args.out
        if out is None:
            root = Path(__file__).resolve().parent.parent
            out = str(root / "BENCH_serving_utilization.json")
        Path(out).write_text(json.dumps({
            "schema_version": SCHEMA_VERSION,
            "benchmark": "serving_utilization",
            "arch": args.arch, "reduced": True,
            "backend": jax.default_backend(),
            "utilization_gate": gate,
            "gate": {"pass": gate["pass"],
                     "metric": "overlapped tick_utilization >= blocking "
                               "(epsilon 0.02) at load 0.9"},
        }, indent=2) + "\n")
        print(f"[bench_serving] wrote {out}")
        if not gate["pass"]:
            print("[bench_serving] utilization gate FAIL: overlapped "
                  "pipeline utilized the device less than blocking")
            sys.exit(1)
        print("[bench_serving] utilization gate OK")
        return

    fault_rates = (tuple(float(x) for x in args.fault_rates.split(","))
                   if args.fault_rates else FAULT_RATES)
    if args.faults_only:
        mcfg = smoke_config(args.arch)
        params = init_params(jax.random.PRNGKey(args.seed), mcfg)
        print(f"[bench_serving] fault sweep only: rates={fault_rates}, "
              f"mode=abfp-packed")
        fault_rows = bench_fault_sweep(params, mcfg, mode="abfp-packed",
                                       seed=args.seed, rates=fault_rates)
        for r in fault_rows:
            print(f"  rate {r['fault_rate']:6.3f} "
                  f"recovery={'on ' if r['recovery'] else 'off'} "
                  f"goodput {r['goodput_per_tick']} "
                  f"(degraded {r['degraded_goodput_per_tick']})  "
                  f"inj {r['injected']} corrupt {r['corrupted']} "
                  f"requeue {r['requeued']} reshards {r['reshards']}")
        ok = fault_gate(fault_rows)
        out = args.out
        if out is None:
            root = Path(__file__).resolve().parent.parent
            out = str(root / "BENCH_serving_faults.json")
        Path(out).write_text(json.dumps({
            "schema_version": SCHEMA_VERSION,
            "benchmark": "serving_fault_sweep",
            "arch": args.arch, "reduced": True,
            "backend": jax.default_backend(),
            "fault_sweep": fault_rows,
            "gate": {"pass": bool(ok),
                     "metric": "goodput recovery-on > recovery-off",
                     "rates": list(fault_rates)},
        }, indent=2) + "\n")
        print(f"[bench_serving] wrote {out}")
        if not ok:
            print("[bench_serving] fault gate FAIL: recovery-on did not "
                  "beat recovery-off at every rate")
            sys.exit(1)
        print("[bench_serving] fault gate OK")
        return

    fleet_archs = (tuple(a for a in args.fleet_archs.split(",") if a)
                   if args.fleet_archs else FLEET_ARCHS)
    if args.fleet_only:
        models = _fleet_models(fleet_archs, args.seed)
        print(f"[bench_serving] fleet only: archs={fleet_archs}")
        fleet_row = bench_fleet(models, mode="float", seed=args.seed)
        for r in fleet_row["per_arch"]:
            print(f"  {r['arch']:20s} {r['runner']:15s} "
                  f"ttft p50 {r['ttft_p50']} p99 {r['ttft_p99']}  "
                  f"tpot p50 {r['tpot_p50']}  "
                  f"completed {r['completed']}/{r['submitted']} "
                  f"preempted {r['preempted']}")
        print(f"  multiplexed {fleet_row['multiplexed']['ticks']} ticks "
              f"({fleet_row['multiplexed']['tok_per_s']} tok/s) vs "
              f"sequential {fleet_row['sequential']['ticks']} ticks "
              f"({fleet_row['sequential']['tok_per_s']} tok/s)")
        quality = fleet_quality_rows(models, seed=args.seed)
        for q in quality:
            print(f"  quality {q['arch']:20s} rel_err {q['rel_err']:.4f} "
                  f"(envelope {q['envelope']})  top1_agree "
                  f"{q['top1_agree']}  "
                  f"{'OK' if q['pass'] else 'FAIL'}")
        ok = fleet_gate(fleet_row, quality)
        out = args.out
        if out is None:
            root = Path(__file__).resolve().parent.parent
            out = str(root / "BENCH_serving_fleet.json")
        Path(out).write_text(json.dumps({
            "schema_version": SCHEMA_VERSION,
            "benchmark": "serving_fleet",
            "archs": list(fleet_archs), "reduced": True,
            "backend": jax.default_backend(),
            "fleet": fleet_row,
            "quality": quality,
            "gate": {"pass": bool(ok),
                     "metric": "per-model conservation AND serving-path "
                               "rel_err <= envelope per arch",
                     "envelope": FLEET_QUALITY_ENVELOPE},
        }, indent=2, default=str) + "\n")
        print(f"[bench_serving] wrote {out}")
        if not ok:
            print("[bench_serving] fleet gate FAIL: conservation or "
                  "quality envelope miss")
            sys.exit(1)
        print("[bench_serving] fleet gate OK")
        return

    overload_loads = (tuple(float(x) for x in args.overload_loads.split(","))
                      if args.overload_loads else OVERLOAD_LOADS)
    if args.overload_only:
        mcfg = smoke_config(args.arch)
        params = init_params(jax.random.PRNGKey(args.seed), mcfg)
        print(f"[bench_serving] overload only: loads={overload_loads}")
        cap = bench_capacity_gate(params, mcfg, seed=args.seed)
        print(f"  capacity @ {cap['kv_budget_tokens']}-token KV budget: "
              f"unpaged {cap['unpaged']['max_concurrent']} "
              f"-> paged {cap['paged']['max_concurrent']} concurrent "
              f"({'OK' if cap['pass'] else 'FAIL'})")
        over_rows = bench_overload_sweep(params, mcfg, seed=args.seed,
                                         loads=overload_loads)
        for r in over_rows:
            print(f"  load {r['load']:3.1f}x "
                  f"{'robust' if r['robust'] else 'seed  '} "
                  f"goodput {r['goodput_per_tick']} "
                  f"ttft p50 {r['ttft_p50']}  shed {r['shed']} "
                  f"preempted {r['preempted']} qdepth<= "
                  f"{r['max_queue_depth']}")
        ok = cap["pass"] and overload_gate(over_rows)
        out = args.out
        if out is None:
            root = Path(__file__).resolve().parent.parent
            out = str(root / "BENCH_serving_overload.json")
        Path(out).write_text(json.dumps({
            "schema_version": SCHEMA_VERSION,
            "benchmark": "serving_overload",
            "arch": args.arch, "reduced": True,
            "backend": jax.default_backend(),
            "capacity_gate": cap,
            "overload_sweep": over_rows,
            "gate": {"pass": bool(ok),
                     "metric": "paged capacity > unpaged AND robust "
                               "goodput >= seed at every load",
                     "loads": list(overload_loads)},
        }, indent=2) + "\n")
        print(f"[bench_serving] wrote {out}")
        if not ok:
            print("[bench_serving] overload gate FAIL")
            sys.exit(1)
        print("[bench_serving] overload gate OK")
        return

    if args.smoke:
        args.prompt_len, args.capacity, args.max_new = 48, 2, 2
        args.max_len, args.modes, args.chunks = 64, "float", "8,16"
        args.loads = "0.8"

    mcfg = smoke_config(args.arch)
    chunks = tuple(int(c) for c in args.chunks.split(","))
    loads = tuple(float(x) for x in args.loads.split(","))
    n_open = args.open_requests or 2 * args.capacity
    params = init_params(jax.random.PRNGKey(args.seed), mcfg)
    print(f"[bench_serving] {args.arch} (reduced): "
          f"{param_count(params)/1e6:.1f}M params, prompt_len="
          f"{args.prompt_len}, capacity={args.capacity}, chunks={chunks}")

    rows, speedups = [], {}
    for mode in args.modes.split(","):
        cell = dict(capacity=args.capacity, prompt_len=args.prompt_len,
                    max_new=args.max_new, max_len=args.max_len,
                    chunks=chunks, seed=args.seed)
        base = bench_cell(params, mcfg, mode=mode, chunked=False, **cell)
        chnk = bench_cell(params, mcfg, mode=mode, chunked=True, **cell)
        rows += [base, chnk]
        speedups[mode] = round(base["ttft_s"] / chnk["ttft_s"], 2)
        print(f"  {mode:12s} ttft {base['ttft_s']:8.3f}s -> "
              f"{chnk['ttft_s']:8.3f}s  ({speedups[mode]:5.1f}x)   "
              f"tok/s {base['tok_per_s']:8.1f} -> {chnk['tok_per_s']:8.1f}   "
              f"ticks {base['ticks']} -> {chnk['ticks']}")

    open_rows = []
    for mode in args.modes.split(","):
        cell = dict(capacity=args.capacity, prompt_len=args.prompt_len,
                    max_new=args.max_new, max_len=args.max_len,
                    chunks=chunks, seed=args.seed)
        calib = calibrate_open_loop(params, mcfg, mode=mode, **cell)
        for load in loads:
            for overlap in ((False, True) if not args.smoke
                            else (False,)):
                row = bench_open_loop(
                    params, mcfg, mode=mode, load=load, overlap=overlap,
                    calib=calib, n_requests=n_open, **cell)
                open_rows.append(row)
                tu = row["tick_utilization"]
                print(f"  {mode:12s} load {load:3.1f} "
                      f"{'overlap ' if overlap else 'blocking'} "
                      f"ttft p50 {row['ttft_p50_s']:7.3f}s "
                      f"p99 {row['ttft_p99_s']:7.3f}s  "
                      f"goodput {row['goodput_rps']} req/s "
                      f"(slo {row['slo_ttft_s']:.3f}s)  "
                      f"util {'-' if tu is None else f'{tu:.2f}'}  "
                      f"qdepth<= {row['max_queue_depth']}")

    mesh_rows = []
    if not args.smoke and not args.no_mesh_sweep:
        print("[bench_serving] per-mesh-shape sweep (forced CPU devices, "
              "subprocess per shape)")
        mesh_rows = mesh_sweep(args)

    fault_rows = []
    if not args.smoke and not args.no_fault_sweep:
        print("[bench_serving] goodput-under-fault-rate sweep "
              "(abfp-packed, simulated clock)")
        fault_rows = bench_fault_sweep(params, mcfg, mode="abfp-packed",
                                       seed=args.seed, rates=fault_rates)
        for r in fault_rows:
            print(f"  rate {r['fault_rate']:6.3f} "
                  f"recovery={'on ' if r['recovery'] else 'off'} "
                  f"goodput {r['goodput_per_tick']} "
                  f"(degraded {r['degraded_goodput_per_tick']})  "
                  f"inj {r['injected']} corrupt {r['corrupted']} "
                  f"requeue {r['requeued']} reshards {r['reshards']}")
        if not fault_gate(fault_rows):
            print("[bench_serving] WARNING: recovery-on did not beat "
                  "recovery-off at every fault rate")

    cap_row, over_rows = None, []
    if not args.smoke and not args.no_overload_sweep:
        print("[bench_serving] capacity gate + overload sweep "
              "(simulated clock)")
        cap_row = bench_capacity_gate(params, mcfg, seed=args.seed)
        over_rows = bench_overload_sweep(params, mcfg, seed=args.seed,
                                         loads=overload_loads)
        if not (cap_row["pass"] and overload_gate(over_rows)):
            print("[bench_serving] WARNING: overload gate failed "
                  "(capacity or goodput regression)")

    fleet_block = None
    if not args.smoke and not args.no_fleet:
        print(f"[bench_serving] heterogeneous fleet bench "
              f"(archs={fleet_archs})")
        fmodels = _fleet_models(fleet_archs, args.seed)
        fleet_row = bench_fleet(fmodels, mode="float", seed=args.seed)
        quality = fleet_quality_rows(fmodels, seed=args.seed)
        for q in quality:
            print(f"  quality {q['arch']:20s} rel_err {q['rel_err']:.4f} "
                  f"{'OK' if q['pass'] else 'FAIL'}")
        fleet_block = {"fleet": fleet_row, "quality": quality,
                       "gate_pass": bool(fleet_gate(fleet_row, quality))}
        if not fleet_block["gate_pass"]:
            print("[bench_serving] WARNING: fleet gate failed "
                  "(conservation or quality envelope)")

    gate_ok = (speedups.get("float", 1.0) >= 1.0)
    result = {
        "schema_version": SCHEMA_VERSION,
        "benchmark": "serving_smoke" if args.smoke else "serving_ttft",
        "arch": args.arch, "reduced": True,
        "prompt_len": args.prompt_len, "capacity": args.capacity,
        "max_new": args.max_new, "prefill_chunks": list(chunks),
        "backend": jax.default_backend(),
        "rows": rows, "speedup_ttft": speedups,
        "open_loop": open_rows,
        "mesh_sweep": mesh_rows,
        "fault_sweep": fault_rows,
        "capacity_gate": cap_row,
        "overload_sweep": over_rows,
        "fleet": fleet_block,
    }
    if args.smoke:
        # Machine-readable gate verdict: CI uploads this artifact, so the
        # measured ratio is visible even (especially) when the gate trips.
        result["gate"] = {"pass": bool(gate_ok),
                          "metric": "speedup_ttft.float",
                          "measured": speedups.get("float"),
                          "threshold": 1.0}

    out = args.out
    if out is None:
        root = Path(__file__).resolve().parent.parent
        out = str(root / ("BENCH_serving_smoke.json" if args.smoke
                          else "BENCH_serving.json"))
    Path(out).write_text(json.dumps(result, indent=2) + "\n")
    print(f"[bench_serving] wrote {out}")

    if args.smoke:
        if not gate_ok:
            print(f"[bench_serving] smoke FAIL: chunked prefill slower "
                  f"than prefill-in-decode ({speedups['float']}x < 1.0)")
            sys.exit(1)
        print(f"[bench_serving] smoke OK: chunked {speedups['float']}x "
              f"faster TTFT")


if __name__ == "__main__":
    main()
