"""Serving benchmark: time-to-first-token and throughput, prefill-in-decode
vs chunked prefill, across numerics modes (float / abfp-kernel / abfp-packed).

Chunked prefill admits prompts in bucketed multi-token chunks (one jitted
pass per chunk, matmuls at M = capacity * chunk) instead of one decode tick
per prompt token, so TTFT drops from O(prompt_len) sequential full-model
passes to O(prompt_len / chunk).

    PYTHONPATH=src python benchmarks/bench_serving.py          # -> BENCH_serving.json
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke  # tiny shapes; asserts
                                                               # chunked is not slower

Timing protocol: each (mode, chunked) cell builds a fresh engine, runs a
small warmup workload that touches every jit shape the timed run needs
(decode tick + each prefill bucket), then times one full workload: TTFT is
wall time from first admission until EVERY request has its first token
(requests == capacity, all admitted at once); throughput is generated
tokens over the full run.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.models import init_params, param_count
from repro.serving import Request, ServingEngine


def _quant(mode: str) -> QuantConfig:
    if mode == "float":
        return QuantConfig(mode="float")
    jmode = {"abfp-kernel": "abfp_kernel", "abfp-packed": "abfp_packed"}[mode]
    return QuantConfig(mode=jmode, tile_width=32, gain=8.0, noise_lsb=0.5)


def _workload(mcfg, n, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, mcfg.vocab_size,
                                        prompt_len).tolist(),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run(eng, reqs):
    """Admit everything, serve to completion.  Returns (ttft_s, total_s,
    generated_tokens, ticks)."""
    ticks0 = eng.ticks
    t0 = time.perf_counter()
    for r in reqs:
        assert eng.try_admit(r), "workload must fit capacity"
    ttft = None
    while any(s is not None for s in eng.slots):
        eng.step()
        if ttft is None and all(r.generated for r in reqs):
            ttft = time.perf_counter() - t0
    total = time.perf_counter() - t0
    return ttft, total, sum(len(r.generated) for r in reqs), eng.ticks - ticks0


def bench_cell(params, mcfg, *, mode, chunked, capacity, prompt_len,
               max_new, max_len, chunks, seed):
    eng = ServingEngine(params, mcfg, capacity=capacity, max_len=max_len,
                        quant=_quant(mode), seed=seed, chunked=chunked,
                        prefill_chunks=chunks)
    # Warmup compiles every shape the timed run could hit: the decode tick
    # and (chunked only) each prefill bucket — one tiny workload per bucket
    # at prompt_len == bucket, so no compile lands in the timed region
    # regardless of --prompt-len.  Warm prompts are capped at max_len - 2
    # (admission guard); the cap selects the same bucket as the largest
    # admissible timed prompt, so every reachable bucket still gets warmed.
    warm_lens = ({min(c, max_len - 2) for c in chunks} if chunked else {2})
    for warm_prompt in sorted(warm_lens):
        _run(eng, _workload(mcfg, min(2, capacity), warm_prompt, 2, seed=99))
    ttft, total, toks, ticks = _run(
        eng, _workload(mcfg, capacity, prompt_len, max_new, seed=seed))
    return {"mode": mode, "chunked": chunked, "ttft_s": round(ttft, 4),
            "total_s": round(total, 4), "tok_per_s": round(toks / total, 2),
            "ticks": ticks}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=320)
    ap.add_argument("--modes", default="float,abfp-kernel,abfp-packed")
    ap.add_argument("--chunks", default="16,64,128")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_serving.json at "
                         "the repo root; --smoke writes nothing by default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, float only; asserts the chunked path "
                         "is not slower than prefill-in-decode")
    args = ap.parse_args()

    if args.smoke:
        args.prompt_len, args.capacity, args.max_new = 48, 2, 2
        args.max_len, args.modes, args.chunks = 64, "float", "8,16"

    mcfg = smoke_config(args.arch)
    chunks = tuple(int(c) for c in args.chunks.split(","))
    params = init_params(jax.random.PRNGKey(args.seed), mcfg)
    print(f"[bench_serving] {args.arch} (reduced): "
          f"{param_count(params)/1e6:.1f}M params, prompt_len="
          f"{args.prompt_len}, capacity={args.capacity}, chunks={chunks}")

    rows, speedups = [], {}
    for mode in args.modes.split(","):
        cell = dict(capacity=args.capacity, prompt_len=args.prompt_len,
                    max_new=args.max_new, max_len=args.max_len,
                    chunks=chunks, seed=args.seed)
        base = bench_cell(params, mcfg, mode=mode, chunked=False, **cell)
        chnk = bench_cell(params, mcfg, mode=mode, chunked=True, **cell)
        rows += [base, chnk]
        speedups[mode] = round(base["ttft_s"] / chnk["ttft_s"], 2)
        print(f"  {mode:12s} ttft {base['ttft_s']:8.3f}s -> "
              f"{chnk['ttft_s']:8.3f}s  ({speedups[mode]:5.1f}x)   "
              f"tok/s {base['tok_per_s']:8.1f} -> {chnk['tok_per_s']:8.1f}   "
              f"ticks {base['ticks']} -> {chnk['ticks']}")

    result = {
        "benchmark": "serving_ttft",
        "arch": args.arch, "reduced": True,
        "prompt_len": args.prompt_len, "capacity": args.capacity,
        "max_new": args.max_new, "prefill_chunks": list(chunks),
        "backend": jax.default_backend(),
        "rows": rows, "speedup_ttft": speedups,
    }
    out = args.out
    if out is None and not args.smoke:
        out = str(Path(__file__).resolve().parent.parent
                  / "BENCH_serving.json")
    if out:
        Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"[bench_serving] wrote {out}")

    if args.smoke:
        assert speedups["float"] >= 1.0, (
            f"chunked prefill slower than prefill-in-decode: "
            f"{speedups['float']}x")
        print(f"[bench_serving] smoke OK: chunked {speedups['float']}x "
              f"faster TTFT")


if __name__ == "__main__":
    main()
