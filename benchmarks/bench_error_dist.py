"""Fig. S1 reproduction — ABFP error distributions vs tile width / gain / noise.

Exact paper protocol (Appendix A): weight matrix (768, 768) ~ Laplace(0,1),
input (16, 25, 768) ~ Normal(0,1) — "a BERT Base projection layer with batch
16, sequence 25" — multiplied in FLOAT32 and ABFP, elementwise difference
dy, 10 repetitions, tiles {8,32,128} x gains {1,2,4,8,16} x ADC noise
{0, 0.5} LSB at 8/8/8.

Quantitative checks of the paper's claims:
  * error variance with noise > without           (Eq. 7)
  * tile 8: error grows with gain                 (saturation)
  * tile 128: error at gain 8 < error at gain 1   (gain recovers LSBs)
  * adaptive per-tile gains (abfp_fused) never do worse than the scalar
    gain at the same cap — the conservative pow2 choice never clips

Also writes ``BENCH_error_dist.json`` (schema_version 2, see
docs/BENCHMARKS.md; override with REPRO_BENCH_JSON=path).
"""

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.abfp import QuantConfig, abfp_matmul, pack_abfp_weight
from repro.kernels.abfp_matmul import abfp_matmul_packed_pallas

TILES = (8, 32, 128)
GAINS = (1.0, 2.0, 4.0, 8.0, 16.0)
NOISES = (0.0, 0.5)
REPS = 10
SCHEMA_VERSION = 2

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_error_dist.json"))


def run(csv_rows: list) -> dict:
    results = {}
    t0 = time.time()
    for tile in TILES:
        for gain in GAINS:
            for noise in NOISES:
                cfg = QuantConfig(tile_width=tile, gain=gain, noise_lsb=noise,
                                  bits_w=8, bits_x=8, bits_y=8,
                                  out_dtype=jnp.float32)

                @jax.jit
                def one_rep(key, cfg=cfg):
                    kw, kx, kn = jax.random.split(key, 3)
                    w = jax.random.laplace(kw, (768, 768), jnp.float32)
                    x = jax.random.normal(kx, (16, 25, 768), jnp.float32)
                    y_ref = jnp.einsum("bsd,dk->bsk", x, w)
                    y_abfp = abfp_matmul(x, w, cfg, kn)
                    return y_abfp - y_ref

                errs = [one_rep(jax.random.fold_in(jax.random.PRNGKey(0), rep))
                        for rep in range(REPS)]
                e = jnp.stack(errs)
                stats = {
                    "mean": float(e.mean()), "std": float(e.std()),
                    "p01": float(jnp.percentile(e, 1)),
                    "p99": float(jnp.percentile(e, 99)),
                    "max_abs": float(jnp.abs(e).max()),
                }
                results[(tile, gain, noise)] = stats
                csv_rows.append(
                    f"error_dist_t{tile}_g{int(gain)}_n{noise},"
                    f"{(time.time() - t0) * 1e6 / REPS:.0f},"
                    f"std={stats['std']:.4f}")

    # ---- adaptive per-tile gains (abfp_fused packing) -------------------
    # Same protocol, packed weights with adaptive_gain=True: per-tile G_t
    # chosen from code statistics under each cap.  Unlike the scalar sweep
    # above, a large cap cannot hurt a small tile — saturating tiles keep
    # G_t = 1 while headroom-rich tiles amplify.
    kw, kx = jax.random.split(jax.random.PRNGKey(7))
    w = jax.random.laplace(kw, (768, 768), jnp.float32)
    x = jax.random.normal(kx, (16, 25, 768), jnp.float32)
    y_ref = jnp.einsum("bsd,dk->bsk", x, w)
    adaptive = {}
    for tile in TILES:
        errs = []
        for cap in GAINS:
            cfg = QuantConfig(mode="abfp_fused", tile_width=tile, gain=cap,
                              noise_lsb=0.0, bits_w=8, bits_x=8, bits_y=8,
                              out_dtype=jnp.float32)
            pw = pack_abfp_weight(w, cfg, adaptive_gain=True)
            e = abfp_matmul_packed_pallas(x, pw, cfg) - y_ref
            std = float(jnp.std(e))
            errs.append(std)
            adaptive[f"t{tile}_g{int(cap)}"] = {
                "std": std,
                "max_gain": float(jnp.max(pw.gains)),
            }
            csv_rows.append(f"error_dist_adaptive_t{tile}_g{int(cap)},"
                            f"{(time.time() - t0) * 1e6 / REPS:.0f},"
                            f"std={std:.4f}")
        # amplification under the adaptive policy never increases error
        assert all(b <= a * (1 + 1e-6) for a, b in zip(errs, errs[1:])), \
            (tile, errs)

    # ---- assertions on the paper's qualitative structure ----
    checks = {
        "noise_widens": results[(32, 2.0, 0.5)]["std"]
        > results[(32, 2.0, 0.0)]["std"],
        "tile8_gain_hurts": results[(8, 16.0, 0.0)]["std"]
        > results[(8, 1.0, 0.0)]["std"],
        "tile128_gain_helps": results[(128, 8.0, 0.0)]["std"]
        < results[(128, 1.0, 0.0)]["std"],
        "small_tile_less_error_at_g1": results[(8, 1.0, 0.0)]["std"]
        < results[(128, 1.0, 0.0)]["std"],
        # The adaptive policy is conservative (never clips), so it may
        # amplify LESS than a lucky scalar gain — but raising the cap can
        # never leave it worse than no amplification at all, at any tile
        # (same weight/input draw: the cap-1 row IS the no-gain baseline).
        "adaptive_never_worse_than_no_gain": all(
            adaptive[f"t{t}_g{int(g)}"]["std"]
            <= adaptive[f"t{t}_g1"]["std"] * (1 + 1e-6)
            for t in TILES for g in GAINS),
        # And where the scalar gain saturates (tile 8, gain 16 hurts), the
        # per-tile choice holds back and stays at the no-gain error.
        "adaptive_avoids_tile8_saturation": (
            adaptive["t8_g16"]["std"]
            < results[(8, 16.0, 0.0)]["std"]),
    }
    assert all(checks.values()), checks
    out = {"results": {str(k): v for k, v in results.items()},
           "adaptive": adaptive, "checks": checks}
    try:
        with open(_JSON_PATH, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "error_dist",
                       "backend": jax.default_backend(),
                       "results": out["results"],
                       "adaptive": adaptive,
                       "checks": {k: bool(v) for k, v in checks.items()}},
                      f, indent=2, sort_keys=True)
        csv_rows.append(f"bench_error_dist_json,0,path={_JSON_PATH}")
    except OSError as e:
        csv_rows.append(f"bench_error_dist_json,0,write_failed={e!r}")
    return out


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
    print("checks:", out["checks"])
