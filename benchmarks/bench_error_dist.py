"""Fig. S1 reproduction — ABFP error distributions vs tile width / gain / noise.

Exact paper protocol (Appendix A): weight matrix (768, 768) ~ Laplace(0,1),
input (16, 25, 768) ~ Normal(0,1) — "a BERT Base projection layer with batch
16, sequence 25" — multiplied in FLOAT32 and ABFP, elementwise difference
dy, 10 repetitions, tiles {8,32,128} x gains {1,2,4,8,16} x ADC noise
{0, 0.5} LSB at 8/8/8.

Quantitative checks of the paper's claims:
  * error variance with noise > without           (Eq. 7)
  * tile 8: error grows with gain                 (saturation)
  * tile 128: error at gain 8 < error at gain 1   (gain recovers LSBs)
"""

import time

import jax
import jax.numpy as jnp

from repro.core.abfp import QuantConfig, abfp_matmul

TILES = (8, 32, 128)
GAINS = (1.0, 2.0, 4.0, 8.0, 16.0)
NOISES = (0.0, 0.5)
REPS = 10


def run(csv_rows: list) -> dict:
    results = {}
    t0 = time.time()
    for tile in TILES:
        for gain in GAINS:
            for noise in NOISES:
                cfg = QuantConfig(tile_width=tile, gain=gain, noise_lsb=noise,
                                  bits_w=8, bits_x=8, bits_y=8,
                                  out_dtype=jnp.float32)

                @jax.jit
                def one_rep(key, cfg=cfg):
                    kw, kx, kn = jax.random.split(key, 3)
                    w = jax.random.laplace(kw, (768, 768), jnp.float32)
                    x = jax.random.normal(kx, (16, 25, 768), jnp.float32)
                    y_ref = jnp.einsum("bsd,dk->bsk", x, w)
                    y_abfp = abfp_matmul(x, w, cfg, kn)
                    return y_abfp - y_ref

                errs = [one_rep(jax.random.fold_in(jax.random.PRNGKey(0), rep))
                        for rep in range(REPS)]
                e = jnp.stack(errs)
                stats = {
                    "mean": float(e.mean()), "std": float(e.std()),
                    "p01": float(jnp.percentile(e, 1)),
                    "p99": float(jnp.percentile(e, 99)),
                    "max_abs": float(jnp.abs(e).max()),
                }
                results[(tile, gain, noise)] = stats
                csv_rows.append(
                    f"error_dist_t{tile}_g{int(gain)}_n{noise},"
                    f"{(time.time() - t0) * 1e6 / REPS:.0f},"
                    f"std={stats['std']:.4f}")

    # ---- assertions on the paper's qualitative structure ----
    checks = {
        "noise_widens": results[(32, 2.0, 0.5)]["std"]
        > results[(32, 2.0, 0.0)]["std"],
        "tile8_gain_hurts": results[(8, 16.0, 0.0)]["std"]
        > results[(8, 1.0, 0.0)]["std"],
        "tile128_gain_helps": results[(128, 8.0, 0.0)]["std"]
        < results[(128, 1.0, 0.0)]["std"],
        "small_tile_less_error_at_g1": results[(8, 1.0, 0.0)]["std"]
        < results[(128, 1.0, 0.0)]["std"],
    }
    assert all(checks.values()), checks
    return {"results": {str(k): v for k, v in results.items()},
            "checks": checks}


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
    print("checks:", out["checks"])
