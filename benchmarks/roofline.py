"""Roofline analysis (deliverable g) — reads the dry-run artifacts.

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s       (197e12 bf16, v5e)
  memory term     = HLO_bytes_per_device / HBM_bw            (819e9 B/s)
  collective term = collective_wire_bytes_per_device / ICI   (~50e9 B/s/link)

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step, the
usefulness ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and a
bottleneck note.  Emits a markdown table (EXPERIMENTS.md §Roofline consumes
it verbatim).
"""

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import param_count
import jax

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def model_flops_per_step(arch: str, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch tokens (1 step);
    inference (no backward): 2*N*D."""
    mcfg = get_config(arch)
    sc = SHAPES[shape_name]

    from repro.models import init_params
    a = jax.eval_shape(lambda k: init_params(k, mcfg), jax.random.PRNGKey(0))
    n_total = param_count(a)
    if mcfg.num_experts:
        # active = non-expert params + top-k/E of expert params
        flat = jax.tree_util.tree_flatten_with_path(a)[0]
        expert_params = sum(
            leaf.size for path, leaf in flat
            if any(getattr(k, "key", None) in ("wi", "wg", "wo") for k in path)
            and any(getattr(k, "key", None) == "moe" for k in path))
        n_active = (n_total - expert_params
                    + expert_params * mcfg.experts_per_token / mcfg.num_experts)
    else:
        n_active = n_total

    if sc.kind == "train":
        tokens = sc.global_batch * sc.seq_len
        return 6.0 * n_active * tokens
    if sc.kind == "prefill":
        tokens = sc.global_batch * sc.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sc.global_batch


def analyze(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    chips = d["chips"]
    flops_dev = max(d["flops_per_device"], 0.0)
    hbm_dev = max(d["hbm_bytes_per_device"], 0.0)
    coll_dev = d["collectives"]["total"]["bytes"]

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = hbm_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops_per_step(d["arch"], d["shape"])
    useful = mf / (flops_dev * chips) if flops_dev > 0 else 0.0
    bound_s = max(terms.values())
    # roofline fraction: useful model flops per second at the bound, vs peak.
    mfu_at_bound = (mf / chips / PEAK_FLOPS_BF16) / bound_s if bound_s else 0.0

    return {
        **{k: d[k] for k in ("arch", "shape", "mesh", "quant", "kind",
                             "chips", "live_bytes_per_device", "fits_16g")},
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": hbm_dev,
        "hbm_bytes_pessimistic": d.get("hbm_bytes_pessimistic", -1.0),
        "collective_bytes_per_device": coll_dev,
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": mfu_at_bound,
    }


_NOTES = {
    "compute_s": "compute-bound: raise MFU via larger per-step math "
                 "(microbatch/fusion) or cut redundant HLO flops (remat)",
    "memory_s": "HBM-bound: fuse/reuse activations, shrink dtype, "
                "re-block to raise arithmetic intensity",
    "collective_s": "ICI-bound: reshard to cut cross-shard traffic, overlap "
                    "collectives with compute, compress gradients",
}


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | quant | compute_s | memory_s | "
           "collective_s | dominant | MODEL_FLOPS | useful | roofline_frac |"
           " fits 16G | note |")
    sep = "|" + "---|" * 13
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                         r["quant"])):
        lines.append(
            "| {arch} | {shape} | {mesh} | {quant} | {compute_s:.2e} | "
            "{memory_s:.2e} | {collective_s:.2e} | {dom} | {mf:.2e} | "
            "{useful:.2f} | {rf:.3f} | {fits} | {note} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                quant=r["quant"], compute_s=r["compute_s"],
                memory_s=r["memory_s"], collective_s=r["collective_s"],
                dom=r["dominant"].replace("_s", ""), mf=r["model_flops"],
                useful=r["useful_ratio"], rf=r["roofline_fraction"],
                fits="yes" if r["fits_16g"] else "NO",
                note=_NOTES[r["dominant"]].split(":")[0]))
    return "\n".join(lines)


def fused_decode_rows() -> list:
    """Analytic fused-decode cells: one fused QKV launch vs three packed
    launches at decode shapes (m=1 / m=8).

    At decode the QKV projections are memory-bound (useful ratio near the
    weight-byte floor), so the win is pure HBM traffic: the fused grid
    streams the activation row once per K-block instead of once per weight,
    and adds only the (T, nj) f32 gains table.  Representative GQA block:
    K=2048, N = 2048 + 256 + 256, tile 32 (kernels/abfp_decode_fused.py;
    measured wall-clock lives in BENCH_kernels.json ``fused_qkv_*`` rows).
    """
    k, cols, tile = 2048, (2048, 256, 256), 32
    t_tiles = -(-k // tile)
    rows = []
    for m in (1, 8):
        n_tot = sum(cols)
        w_bytes = k * n_tot * 1 + t_tiles * n_tot * 2     # int8 codes + bf16
        gains_bytes = t_tiles * (n_tot // 128) * 4        # f32 (T, nj) table
        out_bytes = m * n_tot * 2
        x_bytes = m * k * 4
        three = 3 * x_bytes + w_bytes + out_bytes
        fused = x_bytes + w_bytes + gains_bytes + out_bytes
        rows.append({
            "kind": "fused_decode", "m": m, "k": k, "cols": list(cols),
            "tile": tile,
            "three_call_bytes": three, "fused_bytes": fused,
            "three_call_memory_s": three / HBM_BW,
            "fused_memory_s": fused / HBM_BW,
            "traffic_speedup": three / fused,
        })
    return rows


def run(csv_rows: list) -> dict:
    paths = sorted(glob.glob(os.path.join(ART_DIR, "*.json")))
    rows = []
    for p in paths:
        try:
            r = analyze(p)
        except Exception as e:  # noqa: BLE001
            csv_rows.append(f"roofline_error_{os.path.basename(p)},0,{e!r}")
            continue
        rows.append(r)
        csv_rows.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}_{r['quant']},0,"
            f"dom={r['dominant'].replace('_s','')}"
            f";frac={r['roofline_fraction']:.3f}")
    fused = fused_decode_rows()
    for r in fused:
        csv_rows.append(
            f"roofline_fused_decode_m{r['m']},0,"
            f"traffic_speedup={r['traffic_speedup']:.2f}"
            f";fused_memory_s={r['fused_memory_s']:.2e}")
    md = markdown_table(rows)
    md += ("\n\n### Fused decode step (abfp_fused)\n\n"
           "| m | three-call bytes | fused bytes | traffic speedup |\n"
           "|---|---|---|---|\n")
    for r in fused:
        md += (f"| {r['m']} | {r['three_call_bytes']} | {r['fused_bytes']} "
               f"| {r['traffic_speedup']:.2f}x |\n")
    out_path = os.path.join(ART_DIR, "..", "roofline.md")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write(md)
    return {"rows": rows, "fused_decode": fused,
            "markdown_path": os.path.abspath(out_path)}


if __name__ == "__main__":
    csv: list = []
    out = run(csv)
    print("\n".join(csv))
    print(f"\nwrote {out['markdown_path']} ({len(out['rows'])} cells)")
