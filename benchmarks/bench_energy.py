"""Sec. VI reproduction — the ADC energy comparison against Rekhi et al.

    energy ratio = 2^(12.5-8) / 8 ~= 2.83x less ADC energy
    MACs/cycle   = 128 / 8       =  16x

Also sweeps the design space (tile, bits, gain) to emit the energy-per-MAC
frontier the paper's future-work section sketches.
"""

import itertools

from repro.core.energy import (
    ABFP_RESNET50,
    REKHI_RESNET50,
    AmsDesignPoint,
    energy_per_mac,
    paper_section6_comparison,
)


def run(csv_rows: list) -> dict:
    cmp = paper_section6_comparison()
    csv_rows.append(f"energy_vs_rekhi,0,x={cmp['adc_energy_reduction']:.2f}")
    csv_rows.append(f"macs_per_cycle,0,x={cmp['macs_per_cycle_gain']:.0f}")
    assert abs(cmp["adc_energy_reduction"] - 2.828) < 0.01
    assert cmp["macs_per_cycle_gain"] == 16.0

    frontier = {}
    for tile, bits, gain in itertools.product(
            (8, 32, 128), (6, 8, 10, 12.5), (1, 2, 4, 8, 16)):
        p = AmsDesignPoint(tile_width=tile, adc_bits=bits, gain=gain)
        frontier[(tile, bits, gain)] = energy_per_mac(p)
    # The paper's chosen point dominates Rekhi's on energy/MAC:
    assert energy_per_mac(ABFP_RESNET50) < energy_per_mac(REKHI_RESNET50)
    csv_rows.append(
        f"energy_per_mac_abfp,0,{energy_per_mac(ABFP_RESNET50):.1f}")
    csv_rows.append(
        f"energy_per_mac_rekhi,0,{energy_per_mac(REKHI_RESNET50):.1f}")
    return {"comparison": cmp,
            "frontier": {str(k): v for k, v in frontier.items()}}


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
    print(out["comparison"])
