"""Benchmark driver — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_error_dist    — Fig. S1 (error distributions vs tile/gain/noise)
  bench_quality_grid  — Table II analog (quality grid on a trained LM)
  bench_finetune      — Table III analog (QAT vs DNF + speedup)
  bench_energy        — Sec. VI (2.8x vs Rekhi et al.)
  bench_kernels       — Pallas ABFP kernel vs oracle
  roofline            — deliverable (g): reads the dry-run artifacts
"""

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_energy,
        bench_error_dist,
        bench_finetune,
        bench_kernels,
        bench_quality_grid,
        roofline,
    )

    suites = [
        ("bench_energy", bench_energy.run),
        ("bench_error_dist", bench_error_dist.run),
        ("bench_kernels", bench_kernels.run),
        ("bench_quality_grid", bench_quality_grid.run),
        ("bench_finetune", bench_finetune.run),
        ("roofline", roofline.run),
    ]
    rows: list = ["name,us_per_call,derived"]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        try:
            fn(rows)
            rows.append(f"{name}_total,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
            rows.append(f"{name}_total,{(time.time()-t0)*1e6:.0f},FAILED")
    print("\n".join(rows))
    if failures:
        print(f"FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
