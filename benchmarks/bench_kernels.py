"""Kernel-path benchmark: fused Pallas ABFP matmul vs the einsum oracle and
the scan path, packed (quantize-once) vs unpacked weights, decode-shape
(m=1 / m=8) rows, the fused QKV decode kernel vs three separate packed
launches, and an adaptive per-tile gain accuracy sweep.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times are NOT TPU-indicative; the benchmark's value here is (a) correctness
at realistic shapes, (b) the HBM-traffic accounting — the packed path's
reason to exist: int8 weight codes + bf16 per-tile scales stream ~half the
weight bytes of bf16 weights (and a quarter of f32), and none of the
per-step max/round/clip work — and (c) the relative packed-vs-unpacked
wall-clock at decode shapes, where weight-side work dominates.

Emits ``name,us_per_call,derived`` CSV rows (the benchmarks/run.py
contract) AND a machine-readable JSON file (``BENCH_kernels.json`` at the
repo root, schema_version 2 — see docs/BENCHMARKS.md; override with
REPRO_BENCH_JSON=path).
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abfp import QuantConfig, abfp_matmul, pack_abfp_weight
from repro.kernels.abfp_decode_fused import fused_qkv_packed_pallas
from repro.kernels.abfp_matmul import abfp_matmul_packed_pallas, abfp_matmul_pallas
from repro.kernels.ref import abfp_matmul_ref

SCHEMA_VERSION = 2

# Prefill-ish shapes (oracle + scan cross-check) and decode shapes (m=1/8).
SHAPES = [(256, 2048, 256), (128, 4096, 512)]
DECODE_SHAPES = [(1, 2048, 2048), (8, 2048, 2048)]
# Fused QKV decode shapes: (m, K, (Nq, Nk, Nv)) — a GQA projection block.
FUSED_SHAPES = [(1, 2048, (2048, 256, 256)), (8, 2048, (2048, 256, 256))]
GAIN_SWEEP = (1.0, 2.0, 4.0, 8.0, 16.0)

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_kernels.json"))


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.time() - t0) / reps


def _hbm_bytes(m, k, n, tile, out_itemsize=4):
    """Derived HBM traffic per call for each weight representation.

    Activations (f32 in, one read) and the output write are common; the
    weight side is the differentiator:
      float32  — k*n*4      (what the unpacked kernel streams today)
      bfloat16 — k*n*2      (models' param dtype; the fair baseline)
      packed   — k*n*1 int8 codes + (k/tile)*n*2 bf16 scales
    """
    t_tiles = -(-k // tile)
    common = m * k * 4 + m * n * out_itemsize
    w_f32 = k * n * 4
    w_bf16 = k * n * 2
    w_packed = k * n * 1 + t_tiles * n * 2
    return {
        "common_bytes": common,
        "w_f32_bytes": w_f32,
        "w_bf16_bytes": w_bf16,
        "w_packed_bytes": w_packed,
        "packed_vs_bf16_weight_ratio": w_bf16 / w_packed,
        "unpacked_bytes": common + w_bf16,
        "packed_bytes": common + w_packed,
    }


def run(csv_rows: list) -> dict:
    results = {}

    for (m, k, n) in SHAPES:
        for tile in (32, 128):
            cfg = QuantConfig(tile_width=tile, gain=8.0, noise_lsb=0.0,
                              out_dtype=jnp.float32)
            kx, kw = jax.random.split(jax.random.PRNGKey(0))
            x = (jax.random.normal(kx, (m, k)) * 0.5).astype(jnp.bfloat16)
            w = (jax.random.laplace(kw, (k, n)) * 0.05).astype(jnp.bfloat16)
            pw = pack_abfp_weight(w, cfg)

            scan_fn = jax.jit(lambda x, w: abfp_matmul(x, w, cfg))
            ref_fn = jax.jit(lambda x, w: abfp_matmul_ref(x, w, cfg))
            ker_fn = jax.jit(lambda x, w: abfp_matmul_pallas(x, w, cfg))
            pack_fn = jax.jit(lambda x, pw: abfp_matmul_packed_pallas(x, pw, cfg))

            y_s, t_s = _time(scan_fn, x, w)
            y_r, t_r = _time(ref_fn, x, w)
            y_k, t_k = _time(ker_fn, x, w)
            y_p, t_p = _time(pack_fn, x, pw)
            np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                       rtol=3e-5, atol=3e-5)
            np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_r),
                                       rtol=3e-5, atol=3e-5)
            # Packed must be bit-identical to the unpacked kernel.
            np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_k))

            t_tiles = -(-k // tile)
            hbm = _hbm_bytes(m, k, n, tile)
            # The einsum oracle also materializes (T, M, N) partials twice.
            oracle_bytes = hbm["unpacked_bytes"] + 2 * t_tiles * m * n * 4
            name = f"kernel_m{m}_k{k}_n{n}_t{tile}"
            csv_rows.append(f"{name}_pallas,{t_k*1e6:.0f},"
                            f"hbm_bytes={hbm['unpacked_bytes']}")
            csv_rows.append(f"{name}_packed,{t_p*1e6:.0f},"
                            f"hbm_bytes={hbm['packed_bytes']}")
            csv_rows.append(f"{name}_oracle,{t_r*1e6:.0f},"
                            f"hbm_bytes={oracle_bytes}")
            csv_rows.append(f"{name}_scan,{t_s*1e6:.0f},"
                            f"traffic_ratio={oracle_bytes/hbm['unpacked_bytes']:.1f}")
            results[name] = {
                "m": m, "k": k, "n": n, "tile": tile,
                "pallas_s": t_k, "packed_s": t_p, "oracle_s": t_r,
                "scan_s": t_s,
                "packed_speedup_vs_pallas": t_k / t_p,
                "traffic_ratio": oracle_bytes / hbm["unpacked_bytes"],
                **hbm,
            }

    # Decode shapes: the serving hot path.  auto_bm picks an 8-row block;
    # the packed kernel additionally skips all weight re-quantization.
    for (m, k, n) in DECODE_SHAPES:
        for tile in (32, 128):
            cfg = QuantConfig(tile_width=tile, gain=8.0, noise_lsb=0.0,
                              out_dtype=jnp.bfloat16)
            kx, kw = jax.random.split(jax.random.PRNGKey(1))
            x = (jax.random.normal(kx, (m, k)) * 0.5).astype(jnp.bfloat16)
            w = (jax.random.laplace(kw, (k, n)) * 0.05).astype(jnp.bfloat16)
            pw = pack_abfp_weight(w, cfg)

            ker_fn = jax.jit(lambda x, w: abfp_matmul_pallas(x, w, cfg))
            pack_fn = jax.jit(lambda x, pw: abfp_matmul_packed_pallas(x, pw, cfg))
            y_k, t_k = _time(ker_fn, x, w)
            y_p, t_p = _time(pack_fn, x, pw)
            np.testing.assert_array_equal(np.asarray(y_p, np.float32),
                                          np.asarray(y_k, np.float32))

            hbm = _hbm_bytes(m, k, n, tile, out_itemsize=2)
            name = f"decode_m{m}_k{k}_n{n}_t{tile}"
            csv_rows.append(f"{name}_pallas,{t_k*1e6:.0f},"
                            f"hbm_bytes={hbm['unpacked_bytes']}")
            csv_rows.append(
                f"{name}_packed,{t_p*1e6:.0f},"
                f"hbm_bytes={hbm['packed_bytes']}"
                f";w_ratio={hbm['packed_vs_bf16_weight_ratio']:.2f}"
                f";speedup={t_k/t_p:.2f}")
            results[name] = {
                "m": m, "k": k, "n": n, "tile": tile,
                "pallas_s": t_k, "packed_s": t_p,
                "packed_speedup_vs_pallas": t_k / t_p,
                **hbm,
            }

    # Fused QKV decode step: one launch over the concatenated Q/K/V column
    # space vs three stand-alone packed launches.  One grid amortizes the
    # activation stream (x is read once per K-block instead of three times)
    # and drops two kernel dispatches per decode tick.
    for (m, k, cols) in FUSED_SHAPES:
        tile = 32
        cfg = QuantConfig(mode="abfp_packed", tile_width=tile, gain=8.0,
                          noise_lsb=0.0, out_dtype=jnp.bfloat16)
        kx, kw = jax.random.split(jax.random.PRNGKey(2))
        x = (jax.random.normal(kx, (m, k)) * 0.5).astype(jnp.bfloat16)
        pws = tuple(
            pack_abfp_weight(
                (jax.random.laplace(jax.random.fold_in(kw, i), (k, n))
                 * 0.05).astype(jnp.bfloat16), cfg)
            for i, n in enumerate(cols))

        def three_fn(x, pws=pws):
            return tuple(abfp_matmul_packed_pallas(x, pw, cfg) for pw in pws)

        def fused_fn(x, pws=pws):
            return fused_qkv_packed_pallas(x, pws, cfg)

        y3, t3 = _time(jax.jit(three_fn), x)
        yf, tf = _time(jax.jit(fused_fn), x)
        for a, b in zip(y3, yf):    # the tentpole gate: bit-identical
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

        name = f"fused_qkv_m{m}_k{k}_n{'+'.join(map(str, cols))}_t{tile}"
        csv_rows.append(f"{name}_three_calls,{t3*1e6:.0f},launches=3")
        csv_rows.append(f"{name}_fused,{tf*1e6:.0f},"
                        f"launches=1;speedup={t3/tf:.2f}")
        results[name] = {
            "m": m, "k": k, "cols": list(cols), "tile": tile,
            "three_calls_s": t3, "fused_s": tf,
            "fused_speedup_vs_three_calls": t3 / tf,
        }

    # Adaptive per-tile gain sweep: error vs the FLOAT32 oracle as the gain
    # cap rises.  The conservative pow2 per-tile choice must never increase
    # error (the paper's amplification claim); the sweep lands in the JSON.
    gain_rows = []
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    gx = jax.random.normal(kx, (16, 768), jnp.float32)
    gw = jax.random.laplace(kw, (768, 256), jnp.float32) * 0.04
    g_ref = np.asarray(gx @ gw)
    for tile in (32, 128):
        errs = []
        for cap in GAIN_SWEEP:
            cfg = QuantConfig(mode="abfp_fused", tile_width=tile, gain=cap,
                              noise_lsb=0.0, out_dtype=jnp.float32)
            pw = pack_abfp_weight(gw, cfg, adaptive_gain=True)
            y = np.asarray(abfp_matmul_packed_pallas(gx, pw, cfg))
            err = float(np.mean(np.abs(y - g_ref)))
            errs.append(err)
            gain_rows.append({"tile": tile, "gain_cap": cap,
                              "mean_abs_err": err})
            csv_rows.append(f"gain_sweep_t{tile}_g{int(cap)},0,"
                            f"mean_abs_err={err:.5f}")
        assert all(b <= a * (1 + 1e-6) for a, b in zip(errs, errs[1:])), errs
    results["gain_sweep"] = gain_rows

    try:
        with open(_JSON_PATH, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "kernels",
                       "backend": jax.default_backend(),
                       "results": results}, f, indent=2, sort_keys=True)
        csv_rows.append(f"bench_kernels_json,0,path={_JSON_PATH}")
    except OSError as e:  # read-only checkout: CSV rows still carry the data
        csv_rows.append(f"bench_kernels_json,0,write_failed={e!r}")
    return results


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
    decode = {k: v for k, v in out.items() if k.startswith("decode")}
    for name, r in decode.items():
        print(f"{name}: packed {r['packed_speedup_vs_pallas']:.2f}x vs "
              f"unpacked, weight bytes {r['w_bf16_bytes']} -> "
              f"{r['w_packed_bytes']} "
              f"({r['packed_vs_bf16_weight_ratio']:.2f}x smaller)")
    for name, r in out.items():
        if name.startswith("fused_qkv"):
            print(f"{name}: fused "
                  f"{r['fused_speedup_vs_three_calls']:.2f}x vs three calls")
