"""Kernel-path benchmark: fused Pallas ABFP matmul vs the einsum oracle and
the scan path, plus allclose validation at benchmark shapes.

On this CPU container the Pallas kernel runs in interpret mode, so absolute
times are NOT TPU-indicative; the benchmark's value here is (a) correctness
at realistic shapes and (b) the HBM-traffic accounting (the kernel's reason
to exist: one read of each operand vs the oracle's (T, M, N) materialization
— reported as derived bytes).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abfp import QuantConfig, abfp_matmul
from repro.kernels.abfp_matmul import abfp_matmul_pallas
from repro.kernels.ref import abfp_matmul_ref

SHAPES = [(256, 2048, 256), (128, 4096, 512)]


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.time() - t0) / reps


def run(csv_rows: list) -> dict:
    results = {}
    for (m, k, n) in SHAPES:
        for tile in (32, 128):
            cfg = QuantConfig(tile_width=tile, gain=8.0, noise_lsb=0.0,
                              out_dtype=jnp.float32)
            kx, kw = jax.random.split(jax.random.PRNGKey(0))
            x = (jax.random.normal(kx, (m, k)) * 0.5).astype(jnp.bfloat16)
            w = (jax.random.laplace(kw, (k, n)) * 0.05).astype(jnp.bfloat16)

            scan_fn = jax.jit(lambda x, w: abfp_matmul(x, w, cfg))
            ref_fn = jax.jit(lambda x, w: abfp_matmul_ref(x, w, cfg))
            ker_fn = jax.jit(lambda x, w: abfp_matmul_pallas(x, w, cfg))

            y_s, t_s = _time(scan_fn, x, w)
            y_r, t_r = _time(ref_fn, x, w)
            y_k, t_k = _time(ker_fn, x, w)
            np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                       rtol=3e-5, atol=3e-5)
            np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_r),
                                       rtol=3e-5, atol=3e-5)

            t_tiles = k // tile
            # HBM bytes: fused kernel reads each operand once + writes out;
            # the einsum oracle also materializes (T, M, N) partials twice.
            fused_bytes = (m * k + k * n) * 2 + m * n * 4
            oracle_bytes = fused_bytes + 2 * t_tiles * m * n * 4
            name = f"kernel_m{m}_k{k}_n{n}_t{tile}"
            csv_rows.append(f"{name}_pallas,{t_k*1e6:.0f},"
                            f"hbm_bytes={fused_bytes}")
            csv_rows.append(f"{name}_oracle,{t_r*1e6:.0f},"
                            f"hbm_bytes={oracle_bytes}")
            csv_rows.append(f"{name}_scan,{t_s*1e6:.0f},"
                            f"traffic_ratio={oracle_bytes/fused_bytes:.1f}")
            results[name] = {"pallas_s": t_k, "oracle_s": t_r, "scan_s": t_s,
                             "traffic_ratio": oracle_bytes / fused_bytes}
    return results


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\n".join(rows))
