"""Table III analog — QAT vs DNF recovery, plus the speed claim.

Protocol (paper Sec. V-B, scaled to this container):
  1. train a small LM to convergence in FLOAT;
  2. pick an ABFP config that *degrades* it (harsh: tile 128, low bits);
  3. recover with (a) QAT — ABFP forward + STE backward, and (b) DNF —
     histogram capture once, then FLOAT forward + sampled noise;
  4. report recovered quality as % of FLOAT32 and wall-clock per step.

Checks: both methods improve degraded quality; DNF's per-step time is lower
than QAT's (the paper reports ~4x on A100; the gap here is CPU-sized but
must be > 1).
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.data import DataConfig, batch_at_step
from repro.models import init_params
from repro.optim import AdamW, constant
from repro.training.finetune import capture_histograms, make_dnf_train_step
from repro.training.train_lib import TrainConfig, make_train_step
from benchmarks.bench_quality_grid import accuracy, train_small_lm

FT_STEPS = 40
# Harsh config: tile 8 at gain 4 (Table II's saturation regime) degrades the
# small model visibly AND gives the QAT simulation its real tiled cost
# (d_model/8 = 16 scan steps per dense — at tile 128 the d=128 smoke model
# has ONE tile and the sim is nearly free, making the paper's QAT-vs-DNF
# speed comparison degenerate at smoke scale).
HARSH = QuantConfig(mode="abfp_ref", tile_width=8, gain=4.0,
                    bits_w=4, bits_x=4, bits_y=6, noise_lsb=0.5)


def _timed_steps(step_jit, state, dcfg, n, key):
    # warmup/compile
    state, _ = step_jit(state, batch_at_step(dcfg, 20_000),
                        jax.random.fold_in(key, 0))
    t0 = time.time()
    for i in range(1, n):
        state, metrics = step_jit(state, batch_at_step(dcfg, 20_000 + i),
                                  jax.random.fold_in(key, i))
    jax.block_until_ready(metrics["loss"])
    return state, (time.time() - t0) / max(n - 1, 1)


def run(csv_rows: list) -> dict:
    params, mcfg, dcfg, _ = train_small_lm(seed=1)
    key = jax.random.PRNGKey(7)

    float_acc = accuracy(params, mcfg, dcfg, QuantConfig(mode="float"), key)
    degraded = accuracy(params, mcfg, dcfg, HARSH, key)
    csv_rows.append(f"finetune_baseline,0,float={float_acc:.4f}")
    csv_rows.append(f"finetune_degraded,0,abfp={degraded:.4f}")
    assert degraded < 0.99 * float_acc, (degraded, float_acc)

    # ---- QAT: ABFP forward (STE), paper's AdamW recipe ----
    opt = AdamW(schedule=constant(3e-4))
    init_state, qat_step = make_train_step(
        mcfg, opt, TrainConfig(quant=HARSH))
    state = init_state(params)
    state, qat_s = _timed_steps(jax.jit(qat_step), state, dcfg, FT_STEPS, key)
    qat_acc = accuracy(state.params, mcfg, dcfg, HARSH, key)
    csv_rows.append(f"finetune_qat,{qat_s*1e6:.0f},acc={qat_acc:.4f}")

    # ---- DNF: capture histograms once, FLOAT forward + noise ----
    t0 = time.time()
    cap_batch = batch_at_step(dcfg, 30_000)["tokens"][:, :-1]
    hists, stds = capture_histograms(params, cap_batch, mcfg, HARSH, key=key)
    capture_s = time.time() - t0
    init_state, dnf_step = make_dnf_train_step(mcfg, opt, hists)
    state = init_state(params)
    state, dnf_s = _timed_steps(jax.jit(dnf_step), state, dcfg, FT_STEPS, key)
    dnf_acc = accuracy(state.params, mcfg, dcfg, HARSH, key)
    csv_rows.append(f"finetune_dnf,{dnf_s*1e6:.0f},acc={dnf_acc:.4f}")
    csv_rows.append(f"finetune_dnf_capture,{capture_s*1e6:.0f},"
                    f"layers={len(stds)}")

    speedup = qat_s / dnf_s
    csv_rows.append(f"finetune_dnf_speedup,0,x={speedup:.2f}")

    checks = {
        "qat_recovers": qat_acc > degraded,
        "dnf_recovers": dnf_acc > degraded,
        "dnf_faster_than_qat": speedup > 1.0,
        "layer_stds_finite": all(s >= 0 for s in stds),
    }
    assert all(checks.values()), checks
    return {"float": float_acc, "degraded": degraded, "qat": qat_acc,
            "dnf": dnf_acc, "qat_s": qat_s, "dnf_s": dnf_s,
            "speedup": speedup, "layer_stds": stds, "checks": checks}


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    print("\n".join(rows))
    print("checks:", out["checks"])
