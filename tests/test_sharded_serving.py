"""Sharded-serving parity suite (forced 8-device CPU mesh).

The contract under test: ``ServingEngine(mesh=...)`` emits the SAME greedy
tokens as the single-device engine at every mesh shape — exactly equal for
float mode and bit-identical (noise included) for abfp_packed with a fixed
seed.  Column-parallel tensor parallelism never splits an ABFP K-tile or
reorders an f32 contraction, and the Pallas noise salts are globalized per
column shard (kernels/ops.dense_tp), which is what makes this equality
testable at all.

Runs only when >= 8 jax devices exist — the ``dist`` CI leg forces them
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (see Makefile
``test-dist`` and .github/workflows/ci.yml); on a plain host the module
skips.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.models import init_params
from repro.serving import Request, ServingEngine

pytestmark = [
    pytest.mark.dist,
    pytest.mark.skipif(
        len(jax.devices()) < 8,
        reason="needs 8 devices (run under XLA_FLAGS="
               "--xla_force_host_platform_device_count=8 / make test-dist)"),
]

MESH_SHAPES = [(1, 1), (2, 1), (1, 2), (2, 4)]

# Prompts straddle the (4, 8) prefill buckets: lengths below, at, and above
# a bucket, plus a single-token prompt (routed through the decode tick).
PROMPTS = [[3, 5, 7, 9, 11], [2, 4, 6], [8, 1, 2, 3, 4, 5, 6, 7, 9], [13]]

FLOAT = QuantConfig(mode="float")
PACKED = QuantConfig(mode="abfp_packed", tile_width=32, gain=4.0,
                     noise_lsb=0.5)
# Gain 1.0 pair: the fused decode kernels must be bit-identical to the
# packed dispatch chain (all-ones per-tile gains are exact no-ops).
PACKED1 = QuantConfig(mode="abfp_packed", tile_width=32, gain=1.0,
                      noise_lsb=0.5)
FUSED1 = QuantConfig(mode="abfp_fused", tile_width=32, gain=1.0,
                     noise_lsb=0.5)
FUSED4 = QuantConfig(mode="abfp_fused", tile_width=32, gain=4.0,
                     noise_lsb=0.5)


def _serve(mcfg, params, quant, mesh, *, max_new=4, max_len=32, **ekw):
    eng = ServingEngine(params, mcfg, capacity=4, max_len=max_len,
                        quant=quant, seed=0, prefill_chunks=(4, 8),
                        mesh=mesh, **ekw)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(PROMPTS)]
    done = eng.run(reqs)
    assert len(done) == len(PROMPTS)
    return {r.uid: tuple(r.generated) for r in done}


@pytest.fixture(scope="module")
def tinyllama():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return mcfg, params


@pytest.fixture(scope="module")
def tinyllama_kvq(tinyllama):
    """Same params, int8 KV cache — the fused decode kernel's habitat."""
    mcfg, params = tinyllama
    return dataclasses.replace(mcfg, kv_quant=True), params


@pytest.fixture(scope="module")
def tinyllama_base_float(tinyllama):
    return _serve(*tinyllama, FLOAT, None)


@pytest.fixture(scope="module")
def tinyllama_base_packed(tinyllama):
    return _serve(*tinyllama, PACKED, None)


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_float_parity(tinyllama, tinyllama_base_float, shape):
    """Greedy float decode tokens identical to single-device at any mesh."""
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _serve(*tinyllama, FLOAT, mesh)
    assert got == tinyllama_base_float, shape


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_packed_parity_bit_identical(tinyllama, tinyllama_base_packed,
                                     shape):
    """abfp_packed greedy decode with ADC noise (fixed seed): bit-identical
    tokens to the single-device engine at any mesh shape — the acceptance
    gate for --mesh 2,4 --quant abfp-packed."""
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _serve(*tinyllama, PACKED, mesh)
    assert got == tinyllama_base_packed, shape


@pytest.fixture(scope="module")
def tinyllama_base_packed1_kvq(tinyllama_kvq):
    return _serve(*tinyllama_kvq, PACKED1, None)


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_fused_parity_bit_identical(tinyllama_kvq,
                                    tinyllama_base_packed1_kvq, shape):
    """The tentpole gate: abfp_fused (fused QKV + quantized-KV attention,
    per-tile ADC gains) at gain 1.0 emits bit-identical greedy tokens to
    the single-device abfp_packed engine at EVERY mesh shape — dp-only,
    tp-only, and the full (2, 4) mesh, seeded ADC noise included."""
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _serve(*tinyllama_kvq, FUSED1, mesh)
    assert got == tinyllama_base_packed1_kvq, shape


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_fused_gain_mesh_self_parity(tinyllama_kvq, shape):
    """With real amplification (gain cap 4.0, adaptive per-tile gains) the
    mesh engine matches the single-device FUSED engine bit-for-bit: the
    gains table shards/replicates without perturbing a single logit."""
    base = _serve(*tinyllama_kvq, FUSED4, None)
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _serve(*tinyllama_kvq, FUSED4, mesh)
    assert got == base, shape


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_paged_parity_bit_identical(tinyllama, tinyllama_base_float, shape):
    """Paged decode (replicated page pool, dp-sharded page table) is
    bit-identical to the UNPAGED single-device float baseline at every
    PR-4 mesh shape — the page-table gather must not change a single
    logit under either sharding axis."""
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _serve(*tinyllama, FLOAT, mesh, paged=True, page_size=16)
    assert got == tinyllama_base_float, shape


def test_paged_packed_parity_on_mesh(tinyllama, tinyllama_base_packed):
    """abfp_packed + paged KV at the largest mesh shape: tokens identical
    to the single-device UNPAGED packed engine (seeded ADC noise and the
    quantized KV pool both survive the indirection)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got = _serve(*tinyllama, PACKED, mesh, paged=True, page_size=32)
    assert got == tinyllama_base_packed


@pytest.mark.parametrize("shape", [(1, 2), (2, 4)])
@pytest.mark.parametrize("quant", [FLOAT, PACKED],
                         ids=["float", "abfp_packed"])
def test_ring_cache_wraparound_parity(shape, quant):
    """Hybrid (recurrent + windowed-attention) model whose ring cache WRAPS
    during decode: chunked prefill plus ring wraparound stay bit-identical
    under the mesh.  window=8 with prompt+generated > 8 forces eviction."""
    mcfg = dataclasses.replace(smoke_config("recurrentgemma-2b"),
                               window_size=8)
    assert mcfg.attention_type == "hybrid"
    params = init_params(jax.random.PRNGKey(1), mcfg)
    base = _serve(mcfg, params, quant, None, max_new=6, max_len=48)
    assert any(len(p) + 6 > 8 for p in PROMPTS)     # wraps for long prompts
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _serve(mcfg, params, quant, mesh, max_new=6, max_len=48)
    assert got == base, shape


def test_open_loop_api_unchanged_under_mesh(tinyllama):
    """submit/poll/drain (arrival-driven, priority policy) works unchanged
    on a mesh and matches the single-device engine token-for-token."""
    mcfg, params = tinyllama

    def run(mesh):
        eng = ServingEngine(params, mcfg, capacity=2, max_len=32,
                            quant=FLOAT, seed=0, prefill_chunks=(4, 8),
                            policy="priority", mesh=mesh)
        for i, p in enumerate(PROMPTS):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=3,
                               arrival_time=float(i), priority=i % 2,
                               tenant=f"t{i % 2}"))
        done = eng.drain()
        return {r.uid: tuple(r.generated) for r in done}, eng.ticks

    base_tokens, base_ticks = run(None)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got_tokens, got_ticks = run(mesh)
    assert got_tokens == base_tokens
    assert got_ticks == base_ticks


# ---------------------------------------------------------------------------
# ops-level dispatch: column-parallel bit-identity, row-parallel psum
# ---------------------------------------------------------------------------


def test_dense_tp_col_parallel_bit_identical():
    import jax.numpy as jnp

    from repro.core.abfp import pack_abfp_weight
    from repro.kernels.ops import dense, dense_packed, dense_tp

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (8, 256), jnp.float32)
    w = jax.random.normal(kw, (256, 512), jnp.float32) * 0.1

    cfg_f = QuantConfig(mode="float")
    np.testing.assert_array_equal(
        np.asarray(dense_tp(x, w, cfg_f, None, mesh)),
        np.asarray(dense(x, w, cfg_f)))

    # Packed with noise: tp=4 shards 512 padded columns as 128-lane blocks.
    cfg_p = QuantConfig(mode="abfp_packed", tile_width=32, gain=8.0,
                        noise_lsb=0.5, out_dtype=jnp.float32)
    pw = pack_abfp_weight(w, cfg_p)
    np.testing.assert_array_equal(
        np.asarray(dense_tp(x, pw, cfg_p, kk, mesh)),
        np.asarray(dense_packed(x, pw, cfg_p, kk)))

    cfg_k = cfg_p.replace(mode="abfp_kernel")
    np.testing.assert_array_equal(
        np.asarray(dense_tp(x, w, cfg_k, kk, mesh)),
        np.asarray(dense(x, w, cfg_k, kk)))


def test_dense_tp_fallback_on_indivisible_columns():
    """Columns the mesh cannot split in whole lane blocks run replicated —
    same values, no shard_map error."""
    import jax.numpy as jnp

    from repro.core.abfp import pack_abfp_weight
    from repro.kernels.ops import dense_packed, dense_tp, tp_shardable

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    cfg = QuantConfig(mode="abfp_packed", tile_width=32, gain=4.0,
                      noise_lsb=0.5, out_dtype=jnp.float32)
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(kx, (4, 96), jnp.float32)
    w = jax.random.normal(kw, (96, 130), jnp.float32) * 0.1   # Np=256, tp=8
    pw = pack_abfp_weight(w, cfg)
    assert not tp_shardable(pw, cfg, mesh)
    np.testing.assert_array_equal(
        np.asarray(dense_tp(x, pw, cfg, kk, mesh)),
        np.asarray(dense_packed(x, pw, cfg, kk)))


def test_dense_tp_row_psum_matches_to_tolerance():
    """Contracting-dim (row-parallel) psum: reproducible and allclose, but
    the f32 reduction order differs from single-device — float only, and
    ABFP modes are rejected outright."""
    import jax.numpy as jnp

    from repro.kernels.ops import dense_tp_row

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (8, 256), jnp.float32)
    w = jax.random.normal(kw, (256, 64), jnp.float32) * 0.1
    cfg = QuantConfig(mode="float")
    y = np.asarray(dense_tp_row(x, w, cfg, mesh))
    np.testing.assert_allclose(y, np.asarray(jnp.matmul(x, w)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        y, np.asarray(dense_tp_row(x, w, cfg, mesh)))    # reproducible
    with pytest.raises(ValueError, match="float-only"):
        dense_tp_row(x, w, QuantConfig(mode="abfp_kernel"), mesh)


def test_packed_params_shard_codes_and_scales_together(tinyllama):
    """Placement invariant: every column-sharded PackedWeight shards its
    int8 codes and bf16 scales along the SAME axis with the SAME layout, so
    per-(tile, col) scales live on the shard that owns their codes."""
    from repro.core.abfp import PackedWeight
    from repro.models.packing import pack_model_params

    mcfg, params = tinyllama
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    packed = pack_model_params(params, PACKED, mcfg, mesh=mesh)
    n_sharded = 0
    for leaf in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if not isinstance(leaf, PackedWeight):
            continue
        cspec = leaf.codes.sharding.spec
        sspec = leaf.scales.sharding.spec
        assert tuple(cspec) == tuple(sspec), leaf.shape
        if any(part == "model" for part in cspec):
            n_sharded += 1
            assert tuple(cspec)[-1] == "model"
            assert leaf.n_padded % (2 * 128) == 0
    assert n_sharded > 0        # mlp wi/wg + lm_head shard at tp=2
