"""Overlapped async serving runtime: parity, sync-bug regressions, and
the DeviceStream seam.

Two engine configurations must emit IDENTICAL token streams for greedy
same-seed workloads:

  * the simulated-clock BLOCKING engine (the parity reference every other
    suite gates on), and
  * the wall-clock OVERLAPPED engine (``overlap=True`` + a real clock):
    on-device sampling, unfetched device arrays, dispatch-ahead over a
    bounded delivery queue.

Tokens are sampled inside the jitted pass either way (greedy argmax ties
break first-occurrence, matching ``np.argmax``), so equality is exact in
float mode and bit-identical (seeded ADC noise included) for the ABFP
modes.  The three tick-loop sync bugfixes carry failing-test-first
regressions here:

  1. ``_prefill_pass`` host-synced logits even when every live slot was
     mid-prompt (no recipient) — the fetch is now skipped entirely.
  2. ``StragglerMonitor.observe`` was fed first-execution-per-shape
     dispatch overhead (compile + warmup), escalating on a cold prefill
     bucket mid-trace — first runs are now tagged and excluded.
  3. The idle nap in ``poll()`` returned with ``self.now`` stale from
     before ``time.sleep``, so the next ``submit`` stamped arrivals in
     the past and overstated queue delay — the clock is re-synced after
     the nap.

Every test here is timing-assertion-free (fake clocks only): the
``async`` lane (``make test-async``) must pass on any host, loaded or
not.  Wall-clock THROUGHPUT is benchmarked, not tested — see
``benchmarks/bench_serving.py --utilization-gate``.
"""

import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.distributed.fault import StragglerMonitor
from repro.models import init_params
from repro.serving import (
    DeviceStream,
    OverlappedStream,
    Request,
    ServingEngine,
)
from repro.serving.faults import FaultConfig

pytestmark = [getattr(pytest.mark, "async")]

FLOAT = QuantConfig(mode="float")
PACKED = QuantConfig(mode="abfp_packed", tile_width=32, gain=4.0,
                     noise_lsb=0.5)
FUSED = QuantConfig(mode="abfp_fused", tile_width=32, gain=4.0,
                    noise_lsb=0.5)

# Prompts straddle the (4, 8) prefill buckets plus a single-token prompt
# (decode-tick admission path), same shape family as the sharded suite.
PROMPTS = [[3, 5, 7, 9, 11], [2, 4, 6], [8, 1, 2, 3, 4, 5, 6, 7, 9], [13]]


@pytest.fixture(scope="module")
def tiny():
    mcfg = smoke_config("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return params, mcfg


@pytest.fixture(scope="module")
def tinyllama():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return params, mcfg


def _reqs(n=4, *, prompts=None, max_new=4, temp=0.0, arrival=0.0):
    prompts = prompts if prompts is not None else PROMPTS[:n]
    return [Request(uid=i, prompt=list(p), max_new_tokens=max_new,
                    temperature=temp, arrival_time=arrival)
            for i, p in enumerate(prompts)]


def _outs(done):
    return {r.uid: tuple(r.generated) for r in done}


def _serve_pair(params, mcfg, quant, *, mesh=None, reqs=None, **ekw):
    """Run the same workload through the simulated blocking engine and the
    wall-clock overlapped engine; return (reference, overlapped) outputs
    plus the overlapped engine for extra assertions."""
    kw = dict(capacity=4, max_len=64, quant=quant, seed=0,
              prefill_chunks=(4, 8), mesh=mesh, **ekw)
    ref_eng = ServingEngine(params, mcfg, **kw)
    ref = _outs(ref_eng.run(reqs() if reqs else _reqs()))
    ov_eng = ServingEngine(params, mcfg, clock=time.perf_counter,
                           overlap=True, **kw)
    ov_eng.warmup()
    got = _outs(ov_eng.run(reqs() if reqs else _reqs()))
    ov_eng.close()
    return ref, got, ov_eng


# ---------------------------------------------------------------------------
# Tentpole: overlapped wall-clock == simulated blocking, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [FLOAT, PACKED, FUSED],
                         ids=["float", "abfp_packed", "abfp_fused"])
def test_overlap_parity_single_device(tinyllama, quant):
    params, mcfg = tinyllama
    mcfg = (dataclasses.replace(mcfg, kv_quant=True)
            if quant.mode == "abfp_fused" else mcfg)
    ref, got, eng = _serve_pair(params, mcfg, quant)
    assert got == ref
    assert eng.metrics.conservation()["ok"]


@pytest.mark.dist
@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8 / make test-dist)")
@pytest.mark.parametrize("quant", [FLOAT, PACKED],
                         ids=["float", "abfp_packed"])
def test_overlap_parity_mesh_2x4(tinyllama, quant):
    """The overlapped pipeline under the full (dp, tp) = (2, 4) mesh emits
    the same tokens as the simulated blocking engine on the same mesh."""
    params, mcfg = tinyllama
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ref, got, _ = _serve_pair(params, mcfg, quant, mesh=mesh)
    assert got == ref


def test_overlap_parity_preemption_resume(tiny):
    """A page pool tight enough to force preemptions: the overlapped
    engine preempts, replays, and resumes to the same streams the
    simulated blocking engine produces (count-based slot completion frees
    slots at dispatch, but preemption syncs in-flight passes first)."""
    params, mcfg = tiny
    reqs = lambda: [Request(uid=i, prompt=[(7 * i + j) % 97 + 1
                                           for j in range(20)],
                            max_new_tokens=8, arrival_time=0.0)
                    for i in range(8)]
    kw = dict(paged=True, page_size=16, pool_pages=6, reqs=reqs)
    ref, got, eng = _serve_pair(params, mcfg, FLOAT, **kw)
    cons = eng.metrics.conservation()
    assert cons["preempted"] > 0            # the pool actually saturated
    assert cons["ok"] and cons["preempt_ok"]
    assert got == ref


def test_overlap_parity_fault_recovery(tiny):
    """A fault plan injecting + recovering mid-trace: detection rounds run
    on tick cadence (clock-independent), recovery syncs the pipeline, and
    the requeued re-executions land on the same streams."""
    params, mcfg = tiny
    kw = dict(faults=FaultConfig(rate=0.05, seed=3, horizon=64),
              recovery=True, detect_every=2)
    ref, got, eng = _serve_pair(params, mcfg, PACKED, **kw)
    assert got == ref
    assert eng.metrics.conservation()["ok"]


def test_overlap_temperature_reproducible(tiny):
    """Temperature sampling on the overlapped path draws from the
    on-device seeded stream keyed (seed, uid, token_idx): two runs with
    the same engine seed match exactly; temp=0 slots stay greedy."""
    params, mcfg = tiny

    def run_once():
        eng = ServingEngine(params, mcfg, capacity=4, max_len=64, seed=11,
                            prefill_chunks=(4, 8),
                            clock=time.perf_counter, overlap=True)
        done = eng.run(_reqs(max_new=6, temp=0.8))
        out = _outs(done)
        eng.close()
        return out

    a, b = run_once(), run_once()
    assert a == b
    greedy = ServingEngine(params, mcfg, capacity=4, max_len=64, seed=11,
                           prefill_chunks=(4, 8),
                           clock=time.perf_counter, overlap=True)
    g = _outs(greedy.run(_reqs(max_new=6, temp=0.0)))
    greedy.close()
    assert any(a[u] != g[u] for u in a)     # temperature actually sampled


def test_overlap_streaming_callbacks_in_order(tiny):
    """on_token callbacks fire from the delivery worker in dispatch order
    per request, and every token is delivered exactly once."""
    params, mcfg = tiny
    seen = {}
    reqs = _reqs(max_new=5)
    for r in reqs:
        r.on_token = lambda req, tok: seen.setdefault(req.uid,
                                                      []).append(tok)
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, seed=0,
                        prefill_chunks=(4, 8),
                        clock=time.perf_counter, overlap=True)
    done = eng.run(reqs)
    eng.close()
    assert {u: tuple(t) for u, t in seen.items()} == _outs(done)


def test_overlap_worker_exception_surfaces(tiny):
    """A failing streaming callback on the delivery worker re-raises on
    the engine thread instead of dying silently on the daemon."""
    params, mcfg = tiny
    req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4,
                  arrival_time=0.0)
    req.on_token = lambda r, t: (_ for _ in ()).throw(RuntimeError("boom"))
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32, seed=0,
                        clock=time.perf_counter, overlap=True)
    eng.submit(req)
    with pytest.raises(RuntimeError, match="boom"):
        eng.drain()
    eng._stream._exc = None      # don't re-raise during close
    eng.close()


# ---------------------------------------------------------------------------
# Bugfix 1: no host sync when every live slot is mid-prompt
# ---------------------------------------------------------------------------

def test_midprompt_prefill_pass_does_not_host_sync(tiny):
    """prompt=20 tokens through chunk-4 buckets is 5 prefill passes; only
    the LAST produces a token anyone records.  The blocking engine must
    fetch logits exactly once per recorded token — mid-prompt passes
    perform ZERO device->host transfers — and the streams are unchanged."""
    params, mcfg = tiny
    prompt = [(3 * j) % 97 + 1 for j in range(20)]
    max_new = 3

    def run(**ekw):
        eng = ServingEngine(params, mcfg, capacity=1, max_len=64, seed=0,
                            prefill_chunks=(4,), **ekw)
        done = eng.run([Request(uid=0, prompt=list(prompt),
                                max_new_tokens=max_new, arrival_time=0.0)])
        return eng, _outs(done)

    eng, out = run()
    assert isinstance(eng._stream, DeviceStream)
    # 5 chunk passes: 4 mid-prompt (no sync) + 1 completing (first token),
    # then max_new - 1 decode ticks -> exactly max_new fetches total.
    assert eng._stream.host_syncs == max_new
    assert len(out[0]) == max_new


# ---------------------------------------------------------------------------
# Bugfix 2: straggler monitor ignores first-execution-per-shape overhead
# ---------------------------------------------------------------------------

class _SpyMonitor(StragglerMonitor):
    def __init__(self):
        super().__init__()
        self.samples = []

    def observe(self, step_time):
        self.samples.append(step_time)
        super().observe(step_time)


def test_straggler_excludes_fresh_bucket_warmup(tiny):
    """Force a FRESH prefill bucket mid-trace (a long prompt arrives after
    the engine has only ever compiled the small bucket) on a fake perf
    clock where every first-execution-per-shape costs +99s inside the
    timed region.  The monitor must see only steady-state samples: no
    escalation, no flagged steps."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=64, seed=0,
                        prefill_chunks=(4, 8))
    spy = _SpyMonitor()
    eng.straggler = spy
    eng.metrics.straggler = spy

    t = [0.0]

    def fake_perf():
        t[0] += 0.0005
        return t[0]

    eng._perf = fake_perf
    orig = eng._executable

    def slow_first_run(shape_key, args):
        fn, warm = orig(shape_key, args)
        if warm:
            t[0] += 99.0        # first dispatch of this shape: huge
        return fn, warm

    eng._executable = slow_first_run

    # Request A exercises bucket 4 + the decode shape (>= 5 steady
    # samples); request B then forces the never-seen bucket 8 mid-trace.
    reqs = [Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=8,
                    arrival_time=0.0),
            Request(uid=1, prompt=[5, 6, 7, 8, 9, 10, 11], max_new_tokens=4,
                    arrival_time=0.0)]
    done = eng.run(reqs)
    assert len(done) == 2
    assert {("decode",), ("prefill", 4), ("prefill", 8)} <= eng._warmed_shapes
    assert spy.samples, "steady-state passes must still feed the monitor"
    assert all(dt < 1.0 for dt in spy.samples), spy.samples
    assert spy.flagged == 0


# ---------------------------------------------------------------------------
# Bugfix 3: poll() re-syncs the clock after the idle nap
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_poll_resyncs_clock_after_idle_nap(tiny, monkeypatch):
    """An idle wall-clock poll() naps toward the next arrival.  The nap
    really advances the clock, so ``self.now`` must be re-read afterwards:
    a submit landing right after the poll would otherwise be stamped with
    a pre-sleep arrival and overstate its queue delay by the nap length."""
    import repro.serving.engine as engine_mod
    params, mcfg = tiny
    clk = _FakeClock()
    slept = []

    def fake_sleep(dt):
        slept.append(dt)
        clk.t += dt

    monkeypatch.setattr(engine_mod.time, "sleep", fake_sleep)
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32, seed=0,
                        clock=clk)
    # One future arrival keeps the engine idle-but-not-drained.
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=1,
                       arrival_time=0.5))
    out = eng.poll()
    assert out == [] and slept, "poll must nap toward the future arrival"
    assert eng.now == clk.t     # THE fix: clock re-synced after the nap
    # A submission right after the nap is stamped at the post-sleep time.
    eng.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=1))
    assert eng.metrics.requests[1].arrival_time == clk.t


# ---------------------------------------------------------------------------
# DeviceStream seam + utilization gauge unit behavior
# ---------------------------------------------------------------------------

def test_overlapped_stream_bounded_and_drains():
    class Eng:
        def __init__(self):
            self.seen = []

        def _deliver_ticket(self, ticket):
            self.seen.append(ticket.now)

    from repro.serving.stream import Ticket
    e = Eng()
    s = OverlappedStream(depth=2)
    for k in range(5):
        s.submit(Ticket(engine=e, t0=0.0, warmup=False, sampled=None,
                        recs=[], now=float(k)))
    s.sync()
    assert e.seen == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert s.pending() == 0
    s.close()
    with pytest.raises(RuntimeError):
        s.submit(Ticket(engine=e, t0=0.0, warmup=False, sampled=None,
                        recs=[], now=9.0))


def test_device_span_union_and_windows():
    """tick_utilization merges overlapping spans (counted once) and only
    measures inside open windows — fully idle gaps don't dilute it."""
    from repro.serving.metrics import ServingMetrics
    m = ServingMetrics()
    m.window_open(0.0)
    m.on_device_span(0.0, 1.0)
    m.on_device_span(0.5, 2.0)      # overlaps: union adds only [1, 2]
    m.on_device_span(3.0, 4.0)      # gap [2, 3] is host-idle inside window
    m.window_close(4.0)
    m.window_open(10.0)             # idle [4, 10] never counted
    m.on_device_span(10.0, 11.0)
    m.window_close(11.0)
    u = m.tick_utilization()
    assert u["device_busy_s"] == pytest.approx(4.0)
    assert u["active_s"] == pytest.approx(5.0)
    assert u["value"] == pytest.approx(0.8)
