"""ABFP-quantized KV cache (beyond-paper optimization): correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_decode_state, init_params

B = 2


def test_kv_quant_decode_matches_forward():
    """int8-ABFP cache decode tracks the teacher-forced forward closely."""
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                              mcfg.vocab_size)
    logits_fwd, _ = forward(params, toks, mcfg)

    qcfg = dataclasses.replace(mcfg, kv_quant=True)
    state = init_decode_state(qcfg, B, max_len=16)
    assert state["groups"][0]["kv"]["k"].dtype == jnp.int8
    outs = []
    for t in range(8):
        lg, state = decode_step(params, state, toks[:, t], qcfg)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    # int8 + per-vector scales: small quantization error, high agreement.
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_fwd),
                               rtol=0.05, atol=0.05)
    agree = np.mean(np.argmax(np.asarray(logits_dec), -1)
                    == np.argmax(np.asarray(logits_fwd), -1))
    assert agree == 1.0


def test_kv_quant_cache_memory_halves():
    mcfg = smoke_config("tinyllama-1.1b")
    base = init_decode_state(mcfg, B, max_len=64)
    quant = init_decode_state(dataclasses.replace(mcfg, kv_quant=True), B,
                              max_len=64)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    ratio = nbytes(quant) / nbytes(base)
    assert ratio < 0.60, ratio  # int8 codes + scales vs f32/bf16 cache
