"""Pallas ABFP kernel vs pure-jnp oracle (interpret mode on CPU).

Sweeps shapes, dtypes, tile widths, gains, and block sizes; noise-off runs
must match the oracle to f32-accumulation tolerance, noise-on runs are
validated statistically (the kernel uses a counter-based hash PRNG, the
oracle uses jax.random — same distribution, different streams).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abfp import QuantConfig
from repro.kernels.abfp_matmul import abfp_matmul_pallas
from repro.kernels.ops import dense
from repro.kernels.ref import abfp_matmul_ref


def _rand(mkn, dtype, seed=0):
    m, k, n = mkn
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = (jax.random.normal(kx, (m, k)) * 0.7).astype(dtype)
    w = (jax.random.laplace(kw, (k, n)) * 0.08).astype(dtype)
    return x, w


TOL = dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("tile", [8, 32, 128])
@pytest.mark.parametrize("mkn", [(16, 256, 64), (8, 200, 48), (130, 512, 136)])
def test_kernel_matches_oracle_tiles_shapes(tile, mkn):
    cfg = QuantConfig(tile_width=tile, noise_lsb=0.0, out_dtype=jnp.float32)
    x, w = _rand(mkn, jnp.float32)
    y_k = abfp_matmul_pallas(x, w, cfg)
    y_r = abfp_matmul_ref(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


@pytest.mark.parametrize("gain", [1.0, 2.0, 8.0, 16.0])
def test_kernel_matches_oracle_gain(gain):
    cfg = QuantConfig(tile_width=32, gain=gain, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    x, w = _rand((32, 320, 96), jnp.float32, seed=1)
    y_k = abfp_matmul_pallas(x, w, cfg)
    y_r = abfp_matmul_ref(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


@pytest.mark.parametrize("bits", [(6, 6, 8), (8, 8, 8), (4, 4, 6)])
def test_kernel_matches_oracle_bitwidths(bits):
    bw, bx, by = bits
    cfg = QuantConfig(tile_width=32, bits_w=bw, bits_x=bx, bits_y=by,
                      noise_lsb=0.0, out_dtype=jnp.float32)
    x, w = _rand((16, 256, 64), jnp.float32, seed=2)
    y_k = abfp_matmul_pallas(x, w, cfg)
    y_r = abfp_matmul_ref(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    cfg = QuantConfig(tile_width=8, noise_lsb=0.0, out_dtype=jnp.bfloat16)
    x, w = _rand((24, 128, 72), dtype, seed=3)
    y_k = abfp_matmul_pallas(x, w, cfg)
    y_r = abfp_matmul_ref(x, w, cfg)
    assert y_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32),
        rtol=0.02, atol=0.02,  # bf16 output ULP
    )


@pytest.mark.parametrize("blocks", [(128, 128, None), (64, 64, 64),
                                    (256, 128, 128)])
def test_kernel_block_shape_invariance(blocks):
    bm, bn, bk = blocks
    cfg = QuantConfig(tile_width=32, gain=4.0, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    x, w = _rand((100, 300, 90), jnp.float32, seed=4)
    y_k = abfp_matmul_pallas(x, w, cfg, bm=bm, bn=bn, bk=bk)
    y_r = abfp_matmul_ref(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


def test_kernel_batched_input():
    cfg = QuantConfig(tile_width=32, noise_lsb=0.0, out_dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 160))
    w = jax.random.normal(jax.random.PRNGKey(1), (160, 48)) * 0.1
    y_k = abfp_matmul_pallas(x, w, cfg)
    y_r = abfp_matmul_ref(x, w, cfg)
    assert y_k.shape == (2, 5, 48)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


def test_kernel_noise_statistics():
    """Noise-on: mean error ~ 0, variance ~ T * (n*dY)^2/12 * (sx*sw/G)^2
    aggregated — validated against the noise-off kernel output."""
    cfg_off = QuantConfig(tile_width=128, gain=8.0, noise_lsb=0.0,
                          out_dtype=jnp.float32)
    cfg_on = cfg_off.replace(noise_lsb=0.5)
    x, w = _rand((64, 512, 128), jnp.float32, seed=5)
    y0 = abfp_matmul_pallas(x, w, cfg_off)
    seeds = [jnp.array([s], jnp.int32) for s in range(8)]
    ys = jnp.stack([abfp_matmul_pallas(x, w, cfg_on, s) for s in seeds])
    err = ys - y0[None]
    # Mean across seeds ~ 0 (unbiased noise); different seeds differ.
    assert abs(float(err.mean())) < float(jnp.abs(y0).mean()) * 0.02
    assert float(jnp.abs(ys[0] - ys[1]).max()) > 0.0
    # Oracle noise at the same config has comparable error magnitude.
    y_ref = abfp_matmul_ref(x, w, cfg_on, jax.random.PRNGKey(0))
    ref_rms = float(jnp.sqrt(jnp.mean((y_ref - y0) ** 2)))
    ker_rms = float(jnp.sqrt(jnp.mean(err[0] ** 2)))
    assert 0.5 < ker_rms / max(ref_rms, 1e-12) < 2.0, (ker_rms, ref_rms)


def test_dense_dispatch_and_ste():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96), dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 32)) * 0.1

    y_f = dense(x, w, QuantConfig(mode="float"))
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(x @ w), rtol=1e-6)

    cfg_r = QuantConfig(mode="abfp_ref", tile_width=32, noise_lsb=0.0,
                        out_dtype=jnp.float32)
    cfg_k = cfg_r.replace(mode="abfp_kernel")
    np.testing.assert_allclose(
        np.asarray(dense(x, w, cfg_r)), np.asarray(dense(x, w, cfg_k)), **TOL)

    # STE: gradients equal the plain-matmul gradients for every mode.
    for cfg in (QuantConfig(mode="float"), cfg_r, cfg_k):
        gx, gw = jax.grad(
            lambda x, w: jnp.sum(dense(x, w, cfg).astype(jnp.float32)),
            argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx),
                                   np.asarray(jnp.sum(w, axis=1)[None, :]
                                              * jnp.ones_like(x)), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gw),
                                   np.asarray(jnp.sum(x, axis=0)[:, None]
                                              * jnp.ones_like(w)), rtol=1e-4)


def test_kernel_zero_and_constant_inputs():
    cfg = QuantConfig(tile_width=32, noise_lsb=0.0, out_dtype=jnp.float32)
    x = jnp.zeros((8, 128))
    w = jnp.ones((128, 32))
    y = abfp_matmul_pallas(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y), 0.0)
    # Constant input exactly representable: scale = c, normalized = 1.
    y2 = abfp_matmul_pallas(jnp.full((8, 128), 0.5), w, cfg)
    np.testing.assert_allclose(np.asarray(y2), 32 * 0.5 * 4, rtol=1e-5)
