"""Fused decode kernel + per-tile gain suite (kernels.abfp_decode_fused).

Three contracts:

* BIT-IDENTITY — the fused QKV launch reproduces three stand-alone packed
  kernel calls exactly (noise on/off, decode and small-batch shapes); the
  Pallas quantized-KV attention reproduces the jnp einsum chain exactly;
  and the whole abfp_fused decode tick reproduces the abfp_packed chain at
  gain 1.0 (all-ones per-tile gains are exact f32 no-ops).
* GAIN SEMANTICS — adaptive per-tile gains are powers of two in
  [1, cfg.gain], all ones at gain 1, monotone in the cap, and amplification
  never increases error against the FLOAT32 oracle on random tiles (the
  paper's effective-precision claim).
* ROUND-TRIP — gains survive ``pack_model_params``, the serving engine's
  pack-at-init, and the fault-injection PackedWeight reconstructions.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.abfp import (
    PackedWeight,
    QuantConfig,
    adaptive_tile_gains,
    pack_abfp_weight,
)
from repro.kernels.abfp_decode_fused import (
    fused_qkv_packed_pallas,
    fused_quantized_decode_attention,
)
from repro.kernels.abfp_matmul import abfp_matmul_packed_pallas
from repro.models import decode_step, init_decode_state, init_params
from repro.models.layers import Numerics, quantized_decode_attention
from repro.models.packing import pack_model_params
from repro.serving import Request, ServingEngine


def _mk_qkv(rng, k=256, cols=(384, 128, 128)):
    x = jnp.asarray(rng.normal(size=(1, k)), jnp.float32)
    ws = [jnp.asarray(rng.normal(size=(k, n)) * 0.1, jnp.float32)
          for n in cols]
    return x, ws


# ---------------------------------------------------------------------------
# Fused QKV == three stand-alone packed calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [32, 128])
@pytest.mark.parametrize("noise", [0.0, 0.5])
@pytest.mark.parametrize("m", [1, 8])
def test_fused_qkv_bit_identical_to_packed_calls(tile, noise, m):
    rng = np.random.default_rng(hash((tile, m)) % 2**31)
    cfg = QuantConfig(mode="abfp_packed", tile_width=tile, gain=1.0,
                      noise_lsb=noise)
    x, ws = _mk_qkv(rng)
    x = jnp.tile(x, (m, 1))
    pws = tuple(pack_abfp_weight(w, cfg) for w in ws)
    seeds = (None,) * 3 if noise == 0.0 else tuple(
        jnp.int32(s) for s in (11, 22, 33))
    ref = [abfp_matmul_packed_pallas(x, pw, cfg, s)
           for pw, s in zip(pws, seeds)]
    got = fused_qkv_packed_pallas(x, pws, cfg, seeds)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r, np.float32),
                                      np.asarray(g, np.float32))


def test_fused_qkv_all_ones_gains_bit_identical_to_gain_free():
    """gain=1.0 adaptive pack (all-ones per-tile gains) is bit-identical to
    a gain-free pack: multiplying and dividing by exactly 1.0f changes no
    bits, and f32(adc_base_scale) * 1.0 == f32(adc_code_scale at G=1)."""
    rng = np.random.default_rng(0)
    cfg = QuantConfig(mode="abfp_fused", tile_width=32, gain=1.0,
                      noise_lsb=0.5)
    x, ws = _mk_qkv(rng)
    pws_g = tuple(pack_abfp_weight(w, cfg, adaptive_gain=True) for w in ws)
    pws = tuple(pack_abfp_weight(w, cfg) for w in ws)
    for pw in pws_g:
        assert pw.gains is not None
        np.testing.assert_array_equal(np.asarray(pw.gains), 1.0)
    seeds = tuple(jnp.int32(s) for s in (1, 2, 3))
    for r, g in zip(fused_qkv_packed_pallas(x, pws, cfg, seeds),
                    fused_qkv_packed_pallas(x, pws_g, cfg, seeds)):
        np.testing.assert_array_equal(np.asarray(r, np.float32),
                                      np.asarray(g, np.float32))


def test_fused_qkv_rejects_mismatched_weights():
    rng = np.random.default_rng(3)
    cfg = QuantConfig(mode="abfp_packed", tile_width=32, noise_lsb=0.0)
    x, ws = _mk_qkv(rng)
    pws = [pack_abfp_weight(w, cfg) for w in ws]
    other = pack_abfp_weight(
        jnp.asarray(rng.normal(size=(128, 128)), jnp.float32), cfg)
    with pytest.raises(ValueError, match="share K"):
        fused_qkv_packed_pallas(x, (pws[0], pws[1], other), cfg)
    mixed = dataclasses.replace(
        pws[2], gains=jnp.ones((pws[2].num_tiles,), jnp.float32))
    with pytest.raises(ValueError, match="gains"):
        fused_qkv_packed_pallas(x, (pws[0], pws[1], mixed), cfg)


# ---------------------------------------------------------------------------
# Fused attention == jnp quantized_decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kh,h", [(2, 8), (4, 4)])
def test_fused_attention_bit_identical(kh, h):
    rng = np.random.default_rng(kh * 17 + h)
    B, S, D = 3, 16, 64
    q = jnp.asarray(rng.normal(size=(B, 1, h, D)), jnp.bfloat16)
    kc = jnp.asarray(rng.integers(-127, 128, size=(B, S, kh, D)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, size=(B, S, kh, D)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.1, 2.0, size=(B, S, kh)), jnp.bfloat16)
    vs = jnp.asarray(rng.uniform(0.1, 2.0, size=(B, S, kh)), jnp.bfloat16)
    ln = jnp.asarray([1, 7, 16], jnp.int32)
    ref = quantized_decode_attention(q, kc, ks, vc, vs, lengths=ln)
    got = fused_quantized_decode_attention(q, kc, ks, vc, vs, lengths=ln)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(got, np.float32))


# ---------------------------------------------------------------------------
# Adaptive gain semantics
# ---------------------------------------------------------------------------


def test_adaptive_gains_pow2_bounded_and_monotone():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.laplace(0, 0.05, size=(512, 256)), jnp.float32)
    prev = None
    for cap in (1.0, 2.0, 4.0, 8.0, 16.0):
        cfg = QuantConfig(mode="abfp_fused", tile_width=32, gain=cap,
                          noise_lsb=0.0)
        g = np.asarray(adaptive_tile_gains(pack_abfp_weight(w, cfg), cfg))
        assert g.shape == (512 // 32,)
        assert np.all(g >= 1.0) and np.all(g <= cap)
        np.testing.assert_array_equal(np.log2(g), np.round(np.log2(g)))
        if cap == 1.0:
            np.testing.assert_array_equal(g, 1.0)
        if prev is not None:
            assert np.all(g >= prev)        # raising the cap never lowers G_t
        prev = g


@pytest.mark.parametrize("tile", [32, 128])
def test_gain_sweep_error_monotone_non_increasing(tile):
    """The paper's claim, on random tiles: amplification raises effective
    output precision, so error vs the FLOAT32 oracle never increases as the
    adaptive gain cap grows (the conservative per-tile choice never
    clips)."""
    rng = np.random.default_rng(tile)
    k, n, m = 768, 256, 16
    w = jnp.asarray(rng.laplace(0, 0.04, size=(k, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    ref = np.asarray(x @ w)
    errs = []
    for cap in (1.0, 2.0, 4.0, 8.0, 16.0):
        cfg = QuantConfig(mode="abfp_fused", tile_width=tile, gain=cap,
                          noise_lsb=0.0, out_dtype=jnp.float32)
        pw = pack_abfp_weight(w, cfg, adaptive_gain=True)
        y = np.asarray(abfp_matmul_packed_pallas(x, pw, cfg))
        errs.append(float(np.mean(np.abs(y - ref))))
    for lo_cap, hi_cap in zip(errs, errs[1:]):
        assert hi_cap <= lo_cap * (1 + 1e-6), errs
    assert errs[-1] < errs[0]               # and the knob actually helps


# ---------------------------------------------------------------------------
# Round-trip: pack_model_params, engine, decode parity
# ---------------------------------------------------------------------------

PACKED1 = QuantConfig(mode="abfp_packed", tile_width=32, gain=1.0,
                      noise_lsb=0.5)
FUSED1 = QuantConfig(mode="abfp_fused", tile_width=32, gain=1.0,
                     noise_lsb=0.5)


@pytest.fixture(scope="module")
def tinyllama_kvq():
    mcfg = dataclasses.replace(smoke_config("tinyllama-1.1b"), kv_quant=True)
    return mcfg, init_params(jax.random.PRNGKey(0), mcfg)


def _packed_leaves(tree):
    return [l for l in jax.tree_util.tree_leaves(
                tree, is_leaf=lambda x: isinstance(x, PackedWeight))
            if isinstance(l, PackedWeight)]


def test_gains_round_trip_pack_model_params(tinyllama_kvq):
    mcfg, params = tinyllama_kvq
    fused = _packed_leaves(pack_model_params(params, FUSED1, mcfg))
    plain = _packed_leaves(pack_model_params(params, PACKED1, mcfg))
    assert fused and len(fused) == len(plain)
    assert all(pw.gains is not None for pw in fused)
    assert all(pw.gains.shape == pw.codes.shape[:-2] + (pw.num_tiles,)
               for pw in fused)
    assert all(pw.gains is None for pw in plain)
    # pytree round-trip preserves the gains leaf (engine jit relies on it)
    leaves, treedef = jax.tree_util.tree_flatten(fused[0])
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.gains is not None
    np.testing.assert_array_equal(np.asarray(back.gains),
                                  np.asarray(fused[0].gains))


def test_fused_decode_step_bit_identical_to_packed_chain(tinyllama_kvq):
    """Three greedy ticks through decode_step: the fused kernels (QKV +
    attention) emit the exact logits of the packed dispatch chain at
    gain 1.0, PRNG streams included."""
    mcfg, params = tinyllama_kvq
    key = jax.random.PRNGKey(9)
    tok0 = jnp.asarray([3, 5], jnp.int32)
    outs = {}
    for name, quant in (("packed", PACKED1), ("fused", FUSED1)):
        pk = pack_model_params(params, quant, mcfg)
        st, toks, seq = init_decode_state(mcfg, 2, 16), tok0, []
        for t in range(3):
            logits, st = decode_step(pk, st, toks, mcfg,
                                     Numerics(quant,
                                              jax.random.fold_in(key, t)))
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
            seq.append(np.asarray(logits))
        outs[name] = seq
    for a, b in zip(outs["packed"], outs["fused"]):
        np.testing.assert_array_equal(a, b)


def test_fused_engine_serving_path(tinyllama_kvq):
    """End-to-end runner path: the engine packs with gains at init in fused
    mode and serves bit-identical greedy tokens to the packed engine at
    gain 1.0; at gain 8.0 it still serves (different numerics, same
    schedule)."""
    mcfg, params = tinyllama_kvq
    prompts = [[3, 5, 7], [2], [8, 1, 2, 3, 4]]

    def serve(quant):
        eng = ServingEngine(params, mcfg, capacity=2, max_len=32,
                            quant=quant, seed=0, prefill_chunks=(4, 8))
        gains = [pw.gains for pw in _packed_leaves(eng.params)]
        done = eng.run([Request(uid=i, prompt=list(p), max_new_tokens=4)
                        for i, p in enumerate(prompts)])
        return {r.uid: tuple(r.generated) for r in done}, gains

    base, g_packed = serve(PACKED1)
    got, g_fused = serve(FUSED1)
    assert all(g is None for g in g_packed)
    assert g_fused and all(g is not None for g in g_fused)
    assert got == base

    fused8 = QuantConfig(mode="abfp_fused", tile_width=32, gain=8.0,
                         noise_lsb=0.5)
    got8, g8 = serve(fused8)
    assert sorted(got8) == sorted(base)               # same completions
    assert any(np.asarray(g).max() > 1.0 for g in g8)  # real amplification


def test_dense_dispatch_abfp_fused_packs_on_the_fly():
    """kernels.ops.dense accepts mode="abfp_fused" for raw float weights
    (QAT-style flips): it packs with adaptive gains per call and matches
    the explicit pack + packed-kernel route."""
    from repro.kernels.ops import dense, dense_packed

    rng = np.random.default_rng(11)
    cfg = QuantConfig(mode="abfp_fused", tile_width=32, gain=8.0,
                      noise_lsb=0.5, out_dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)) * 0.1, jnp.float32)
    key = jax.random.PRNGKey(1)
    pw = pack_abfp_weight(w, cfg, adaptive_gain=True)
    np.testing.assert_array_equal(
        np.asarray(dense(x, w, cfg, key)),
        np.asarray(dense_packed(x, pw, cfg, key)))


def test_faults_preserve_gains():
    """Every fault/repair PackedWeight reconstruction keeps the gains leaf
    (dropping it would silently change fused-mode numerics mid-serve)."""
    from repro.serving.faults import inject_scale_drift, inject_stuck_cols

    rng = np.random.default_rng(13)
    cfg = QuantConfig(mode="abfp_fused", tile_width=32, gain=8.0,
                      noise_lsb=0.0)
    w = jnp.asarray(rng.normal(size=(128, 128)) * 0.1, jnp.float32)
    params = {"wq": pack_abfp_weight(w, cfg, adaptive_gain=True)}
    g0 = np.asarray(params["wq"].gains)
    hurt = inject_stuck_cols(params, "wq", [0, 3])
    np.testing.assert_array_equal(np.asarray(hurt["wq"].gains), g0)
    hurt = inject_scale_drift(params, "wq", [(0, 1)], [1.5])
    np.testing.assert_array_equal(np.asarray(hurt["wq"].gains), g0)
