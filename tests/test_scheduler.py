"""Arrival-driven serving: scheduler policies, SLO metrics, the open-loop
submit/poll engine API, streaming callbacks, and temperature sampling.

Everything here runs on the SIMULATED clock (one jitted pass == one tick),
so ordering and latency assertions are exact, not statistical.
"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_params
from repro.serving import (
    Request,
    ServingEngine,
    ServingMetrics,
    get_scheduler,
    percentile_summary,
)


def _req(uid, *, plen=1, arrival=0.0, priority=0, tenant="default",
         max_new=2, **kw):
    return Request(uid=uid, prompt=list(range(1, plen + 1)),
                   max_new_tokens=max_new, arrival_time=arrival,
                   priority=priority, tenant=tenant, **kw)


# ---------------------------------------------------------------------------
# Pure scheduler-policy tests (no model, no jit)
# ---------------------------------------------------------------------------

def _pop_all(sched, now):
    out = []
    while True:
        r = sched.pop(now)
        if r is None:
            return [x.uid for x in out]
        out.append(r)


def test_fcfs_orders_by_arrival_then_submit():
    s = get_scheduler("fcfs")
    s.add(_req(0, arrival=2.0))
    s.add(_req(1, arrival=0.0))
    s.add(_req(2, arrival=2.0))   # ties broken by submit order
    s.add(_req(3, arrival=1.0))
    assert _pop_all(s, now=10.0) == [1, 3, 0, 2]


def test_sjf_orders_by_prompt_length():
    s = get_scheduler("sjf")
    s.add(_req(0, plen=30))
    s.add(_req(1, plen=2))
    s.add(_req(2, plen=2))        # equal length: submit order
    s.add(_req(3, plen=9))
    assert _pop_all(s, now=0.0) == [1, 2, 3, 0]


def test_fcfs_vs_sjf_disagree_on_the_same_workload():
    reqs = [(_req(0, plen=30, arrival=0.0), _req(1, plen=2, arrival=0.5))]
    fcfs, sjf = get_scheduler("fcfs"), get_scheduler("sjf")
    for a, b in reqs:
        fcfs.add(a), fcfs.add(b)
    for a, b in reqs:
        sjf.add(a), sjf.add(b)
    assert _pop_all(fcfs, now=1.0) == [0, 1]
    assert _pop_all(sjf, now=1.0) == [1, 0]


def test_arrival_gating_and_next_arrival():
    s = get_scheduler("fcfs")
    s.add(_req(0, arrival=5.0))
    assert s.pop(now=4.9) is None      # nothing has arrived yet
    assert s.next_arrival() == 5.0
    assert s.pending(4.9) == 0 and len(s) == 1
    assert s.pop(now=5.0).uid == 0
    assert s.next_arrival() is None


def test_priority_classes_dominate():
    s = get_scheduler("priority")
    s.add(_req(0, priority=0))
    s.add(_req(1, priority=2))
    s.add(_req(2, priority=1))
    assert _pop_all(s, now=0.0) == [1, 2, 0]


def test_priority_tenant_fairness_under_saturation():
    """Tenant A floods the queue first; same-priority admissions must still
    alternate A/B instead of draining A."""
    s = get_scheduler("priority")
    for i in range(3):
        s.add(_req(i, tenant="A"))
    for i in range(3, 6):
        s.add(_req(i, tenant="B"))
    order = _pop_all(s, now=0.0)
    tenants = ["A" if u < 3 else "B" for u in order]
    assert tenants == ["A", "B", "A", "B", "A", "B"]


def test_priority_beats_fairness_across_classes():
    s = get_scheduler("priority")
    s.add(_req(0, tenant="A", priority=0))
    s.add(_req(1, tenant="A", priority=1))
    s.add(_req(2, tenant="B", priority=0))
    # Tenant A already got an admission, but priority 1 still preempts the
    # fairness rotation (fairness is WITHIN a class, not across).
    assert _pop_all(s, now=0.0) == [1, 2, 0]


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_scheduler("round-robin")


# ---------------------------------------------------------------------------
# Pure metrics tests
# ---------------------------------------------------------------------------

def test_metrics_hand_computed():
    m = ServingMetrics(capacity=2)
    m.on_submit(7, arrival_time=1.0, tenant="A", prompt_len=4)
    m.on_admit(7, 2.0)
    m.on_token(7, 3.0)     # first token: TTFT = 3 - 1
    m.on_token(7, 4.0)
    m.on_token(7, 6.0)
    m.on_finish(7, 6.0)
    r = m.requests[7]
    assert r.queue_delay == 1.0
    assert r.ttft == 2.0
    assert r.e2e == 5.0
    assert r.tpot == pytest.approx((6.0 - 3.0) / 2)   # 2 inter-token gaps
    s = m.summary()
    assert s["requests"] == {"submitted": 1, "finished": 1, "rejected": 0,
                             "timed_out": 0, "shed": 0, "preempted": 0,
                             "resumed": 0, "requeued": 0, "corrupted": 0,
                             "conservation_ok": True, "preempt_ok": True}
    assert s["ttft"]["p50"] == 2.0 and s["ttft"]["n"] == 1
    # goodput: 1 request over the arrival->finish span of 5 ticks
    assert m.goodput(slo_ttft=2.0) == pytest.approx(1 / 5)
    assert m.goodput(slo_ttft=1.9) == 0.0


def test_metrics_utilization_and_queue_depth():
    m = ServingMetrics(capacity=4)
    m.on_tick(0.0, live=2, capacity=4, queue_depth=3)
    m.on_tick(1.0, live=4, capacity=4, queue_depth=0)
    s = m.summary()
    assert s["utilization"]["mean"] == pytest.approx(0.75)
    assert s["queue_depth"] == {"mean": 1.5, "max": 3}
    assert s["ticks"] == 2


def test_metrics_uid_reuse_starts_fresh():
    """Serving a second workload that reuses uids on the same engine must
    not inherit the first workload's token timestamps."""
    m = ServingMetrics()
    m.on_submit(0, arrival_time=0.0)
    m.on_admit(0, 0.0)
    m.on_token(0, 1.0)
    m.on_finish(0, 1.0)
    m.on_submit(0, arrival_time=50.0)     # same uid, new request
    m.on_admit(0, 50.0)
    m.on_token(0, 52.0)
    m.on_finish(0, 52.0)
    r = m.requests[0]
    assert r.n_tokens == 1 and r.ttft == 2.0 and r.e2e == 2.0
    # Direct try_admit() path (no submit): a finished record is replaced.
    m2 = ServingMetrics()
    m2.on_admit(7, 0.0)
    m2.on_token(7, 1.0)
    m2.on_finish(7, 1.0)
    m2.on_admit(7, 10.0)
    m2.on_token(7, 13.0)
    assert m2.requests[7].ttft == 3.0 and m2.requests[7].n_tokens == 1


def test_percentile_summary_empty_and_none_filtering():
    s = percentile_summary([None, None])
    assert s["p50"] is None and s["n"] == 0
    s = percentile_summary([1.0, None, 3.0])
    assert s["n"] == 2 and s["p50"] == 2.0 and s["max"] == 3.0


def test_metrics_json_roundtrip(tmp_path):
    import json
    m = ServingMetrics()
    m.on_admit(0, 0.0)
    m.on_token(0, 1.0)
    m.on_finish(0, 1.0)
    path = tmp_path / "metrics.json"
    m.to_json(path, policy="sjf")
    doc = json.loads(path.read_text())
    assert doc["policy"] == "sjf"
    assert doc["ttft"]["p99"] == 1.0


# ---------------------------------------------------------------------------
# Engine integration (simulated clock, tiny model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    mcfg = smoke_config("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return params, mcfg


def test_open_loop_ttft_tpot_hand_computed(tiny):
    """capacity=1: r0 arrives at 0 (prompt fits one chunk -> 1 prefill pass,
    first token at t=1, then one decode tick per token); r1 arrives at 0 but
    must wait for the slot."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32,
                        prefill_chunks=(8,), policy="fcfs")
    r0 = _req(0, plen=4, arrival=0.0, max_new=3)
    r1 = _req(1, plen=4, arrival=0.0, max_new=2)
    assert eng.submit(r0) and eng.submit(r1)
    done = eng.drain()
    assert [r.uid for r in done] == [0, 1]
    m0, m1 = eng.metrics.requests[0], eng.metrics.requests[1]
    # r0: admitted at 0, prefill pass -> first token at t=1, decode ticks
    # at t=2, t=3 -> TTFT 1, TPOT (3-1)/2 = 1, E2E 3.
    assert m0.admit_time == 0.0 and m0.ttft == 1.0
    assert m0.tpot == 1.0 and m0.e2e == 3.0
    # r1: slot frees when r0 finishes at t=3 -> admit 3, first token 4,
    # second 5 -> TTFT 4, E2E 5.
    assert m1.admit_time == 3.0 and m1.ttft == 4.0 and m1.e2e == 5.0
    assert eng.metrics.ticks == 5
    s = eng.metrics.summary()
    assert s["utilization"]["mean"] == 1.0          # capacity-1, always busy
    assert s["queue_depth"]["max"] == 1             # r1 waiting during r0


def test_idle_engine_jumps_to_next_arrival(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32,
                        prefill_chunks=(8,))
    eng.submit(_req(0, plen=1, arrival=100.0, max_new=1))
    done = eng.drain()
    assert len(done) == 1
    m = eng.metrics.requests[0]
    assert m.admit_time == 100.0 and m.ttft == 1.0  # no idle-tick burn
    assert eng.metrics.ticks == 1


def test_streaming_callback_token_order(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=2, max_len=32,
                        prefill_chunks=(8,))
    streams = {0: [], 1: []}
    reqs = [_req(i, plen=3 + i, arrival=0.0, max_new=4,
                 on_token=lambda r, t: streams[r.uid].append(t))
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.drain()
    for r in reqs:
        assert streams[r.uid] == r.generated        # exact order, no drops
        assert len(r.generated) == 4


def test_run_wrapper_equals_submit_poll_fcfs(tiny):
    """run() is a thin wrapper over submit()/drain(): same workload, same
    seed => bit-identical generations and tick count."""
    params, mcfg = tiny
    rng = np.random.default_rng(3)
    lens = [(5, 4), (9, 3), (2, 2), (7, 3), (1, 2)]

    def workload():
        r = np.random.default_rng(7)
        return [Request(uid=i,
                        prompt=r.integers(1, mcfg.vocab_size, n).tolist(),
                        max_new_tokens=m)
                for i, (n, m) in enumerate(lens)]

    del rng
    e1 = ServingEngine(params, mcfg, capacity=2, max_len=32, seed=1)
    done1 = e1.run(workload())
    e2 = ServingEngine(params, mcfg, capacity=2, max_len=32, seed=1)
    for r in workload():
        e2.submit(r)
    done2 = e2.drain()
    assert [r.uid for r in done1] == [r.uid for r in done2]
    assert ([r.generated for r in done1] == [r.generated for r in done2])
    assert e1.ticks == e2.ticks


def test_oversized_request_rejected_and_counted(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=8,
                        prefill_chunks=(8,))
    bad = _req(0, plen=20, max_new=4)
    ok = _req(1, plen=2, max_new=2)
    done = eng.run([bad, ok])
    assert done[0] is bad and bad.done and bad.generated == []
    assert len(done) == 2 and done[1] is ok and len(ok.generated) == 2
    s = eng.metrics.summary()
    assert s["requests"]["rejected"] == 1
    assert s["requests"]["finished"] == 1


def test_priority_policy_preempts_admission_order(tiny):
    """capacity=1 saturated: the high-priority late arrival is admitted
    before earlier low-priority submissions."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32,
                        prefill_chunks=(8,), policy="priority")
    reqs = [_req(0, plen=2, arrival=0.0, priority=0, max_new=2),
            _req(1, plen=2, arrival=0.0, priority=0, max_new=2),
            _req(2, plen=2, arrival=0.0, priority=5, max_new=2)]
    for r in reqs:
        eng.submit(r)
    done = eng.drain()
    # All three are queued before the first poll, so the priority-5 request
    # is admitted first; the two priority-0 requests then run in submit
    # order.
    assert [r.uid for r in done] == [2, 0, 1]


# ---------------------------------------------------------------------------
# Temperature sampling (engine PRNG stream)
# ---------------------------------------------------------------------------

def test_temperature_zero_is_greedy_and_seed_independent(tiny):
    """temperature=0.0 must stay bit-identical to the greedy path: the
    sampling stream (engine seed) must not touch it.  In float mode the
    logits are seed-independent, so two engines with different seeds must
    produce identical greedy outputs."""
    params, mcfg = tiny
    outs = []
    for eng_seed in (0, 123):
        eng = ServingEngine(params, mcfg, capacity=2, max_len=32,
                            seed=eng_seed)
        reqs = [Request(uid=i, prompt=[3, 5, 7], max_new_tokens=4,
                        temperature=0.0) for i in range(2)]
        eng.run(reqs)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1]


def test_temperature_sampling_reproducible_and_seeded(tiny):
    params, mcfg = tiny

    def sample(eng_seed):
        eng = ServingEngine(params, mcfg, capacity=2, max_len=32,
                            seed=eng_seed)
        reqs = [Request(uid=i, prompt=[2 + i, 9], max_new_tokens=6,
                        temperature=1.5) for i in range(2)]
        eng.run(reqs)
        return [r.generated for r in reqs]

    a, b = sample(0), sample(0)
    assert a == b                       # same engine seed => bit-identical
    c = sample(42)
    assert a != c                       # the stream is engine-seeded


def test_temperature_draws_independent_of_interleaving(tiny):
    """The sampling stream is keyed by (seed, uid, token index), not by
    tick order: the same request sampled alone or alongside another request
    sees the same draws (float mode => identical logits => identical
    tokens)."""
    params, mcfg = tiny
    target = Request(uid=5, prompt=[4, 4, 4], max_new_tokens=5,
                     temperature=0.9)
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32, seed=0)
    eng.run([target])
    alone = list(target.generated)

    target2 = Request(uid=5, prompt=[4, 4, 4], max_new_tokens=5,
                      temperature=0.9)
    other = Request(uid=9, prompt=[8] * 7, max_new_tokens=5,
                    temperature=0.9)
    eng = ServingEngine(params, mcfg, capacity=2, max_len=32, seed=0)
    eng.run([other, target2])
    assert target2.generated == alone


# ---------------------------------------------------------------------------
# capacity == 1 corner: admission through a single slot must never wedge
# ---------------------------------------------------------------------------
#
# The ISSUE-5 satellite: ``_reset_slot`` and ``fits()`` had no coverage for
# the single-slot engine, where every admission recycles the one slot state
# and any drained-but-unpolled request must free it for the queue behind.
# These pin the corner: back-to-back recycling through poll()/drain(), the
# prompt + max_new == max_len admission boundary, legacy prefill-in-decode,
# and the manual try_admit()/step() API where the caller never polls.


def test_capacity_one_recycles_slot_through_drain(tiny):
    """Three queued requests funnel through one slot: conservation holds,
    the slot and queue end empty, and every admission reset the slot (each
    request decodes from ITS OWN prompt, not leftover state)."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=16,
                        prefill_chunks=(4,))
    reqs = [_req(0, plen=5, max_new=3), _req(1, plen=1, max_new=2),
            _req(2, plen=7, max_new=3)]
    for r in reqs:
        assert eng.submit(r)
    done = eng.drain()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)
    assert eng.slots == [None] and len(eng.scheduler) == 0

    # Same prompts served one-per-engine give identical tokens: the slot
    # reset between occupants leaked nothing.
    for r in reqs:
        solo = ServingEngine(params, mcfg, capacity=1, max_len=16,
                             prefill_chunks=(4,))
        q = _req(r.uid, plen=len(r.prompt), max_new=r.max_new_tokens)
        solo.run([q])
        assert q.generated == r.generated, r.uid


def test_capacity_one_admits_at_max_len_boundary(tiny):
    """prompt + max_new == max_len is admissible (fits() boundary) and must
    complete through the single slot, including a successor request."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=12,
                        prefill_chunks=(4, 8))
    boundary = _req(0, plen=8, max_new=4)           # 8 + 4 == max_len
    succ = _req(1, plen=2, max_new=2)
    assert eng.fits(boundary)
    assert not eng.fits(_req(9, plen=9, max_new=4))  # one past: rejected
    for r in (boundary, succ):
        assert eng.submit(r)
    done = eng.drain()
    assert [len(r.generated) for r in done] == [4, 2]
    assert eng.slots == [None]


def test_capacity_one_legacy_prefill_in_decode(tiny):
    """chunked=False: the one slot consumes prompts a token per tick and
    still recycles cleanly."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=16, chunked=False)
    reqs = [_req(0, plen=3, max_new=2), _req(1, plen=2, max_new=2)]
    done = eng.run(reqs)
    assert sorted(r.uid for r in done) == [0, 1]
    assert all(len(r.generated) == 2 for r in done)


def test_capacity_one_drained_unpolled_slot_frees_for_manual_admit(tiny):
    """Manual try_admit()/step() (no poll()): when the only slot's request
    drains its token budget, the slot must free IMMEDIATELY — a follow-up
    try_admit in the same tick loop succeeds instead of deadlocking, and
    completion flushing does not depend on ever calling poll()."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=16,
                        prefill_chunks=(4,))
    a = _req(0, plen=4, max_new=2)
    assert eng.try_admit(a)
    for _ in range(8):
        if a.done:
            break
        eng.step()
    assert a.done and eng.slots == [None]
    b = _req(1, plen=2, max_new=2)
    assert eng.try_admit(b), "slot still held by a drained request"
    while not b.done:
        eng.step()
    assert len(b.generated) == 2


# ---------------------------------------------------------------------------
# Requeue x expire interaction (fault recovery meets deadlines)
# ---------------------------------------------------------------------------

def test_requeued_request_past_deadline_times_out_not_readmitted(tiny):
    """Regression: a request requeued by fault recovery whose deadline has
    ALREADY passed must be timed out on the next admission pass — never
    re-admitted into a slot (which would stamp a bogus admit_time and burn
    a slot reset on a request that can only expire)."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32,
                        prefill_chunks=(8,))
    r = _req(0, plen=4, arrival=0.0, max_new=8, deadline=3.0)
    assert eng.submit(r)
    eng.poll()                              # admitted at t=0, prefill at t=1
    admit0 = eng.metrics.requests[0].admit_time
    assert admit0 == 0.0 and not r.done
    # Fault-recovery eviction: slot state is discarded and the request goes
    # back through the scheduler with arrival order preserved.
    eng.slots = [None] * eng.capacity
    r.prompt_pos = 0
    r.generated.clear()
    eng.metrics.on_requeue(r.uid)
    eng.scheduler.requeue(r)
    eng.now = 5.0                           # deadline (3.0) already past
    done = eng.drain()
    assert [x.uid for x in done] == [0]
    assert r.timed_out and r.done and not r.generated
    rec = eng.metrics.requests[0]
    assert rec.timed_out and rec.n_tokens == 0
    # Not re-admitted: admit_time keeps its original stamp instead of being
    # overwritten by a doomed re-admission after the deadline.
    assert rec.admit_time == admit0
    assert eng.metrics.conservation()["ok"]


# ---------------------------------------------------------------------------
# Admission-filter plumbing + paged fits() relaxation + backpressure
# ---------------------------------------------------------------------------

def test_peek_matches_pop_and_remove_keeps_fairness():
    s = get_scheduler("priority")
    s.add(_req(0, tenant="a", priority=1))
    s.add(_req(1, tenant="a", priority=1))
    s.add(_req(2, tenant="b", priority=1))
    head = s.peek(0.0)
    assert head.uid == 0 and len(s) == 3    # peek never dequeues
    s.remove(head)                          # out-of-band admit (page claim)
    # remove() fired the fairness hook: tenant "a" now trails "b", so the
    # round-robin key admits b's request before a's second one.
    assert s.pop(0.0).uid == 2
    assert s.pop(0.0).uid == 1


def test_pop_admissible_skips_without_dequeuing():
    s = get_scheduler("fcfs")
    s.add(_req(0, tenant="blocked"))
    s.add(_req(1, tenant="ok"))
    ok = lambda r: r.tenant != "blocked"
    assert s.peek(0.0, ok).uid == 1
    assert s.pop(0.0, ok).uid == 1
    assert s.pop(0.0, ok) is None           # blocked head is skipped...
    assert len(s) == 1                      # ...but never dequeued
    assert s.pop(0.0).uid == 0              # unfiltered pop still sees it


def test_fits_legacy_vs_paged_budget(tiny):
    """Satellite: the hard ``prompt + max_new <= max_len`` reject only
    applies to the unpaged engine; under paging, admission is a PAGE
    budget check (``max_pages * page_size`` addressable tokens)."""
    params, mcfg = tiny
    legacy = ServingEngine(params, mcfg, capacity=1, max_len=40,
                           prefill_chunks=(8,))
    paged = ServingEngine(params, mcfg, capacity=1, max_len=40,
                          prefill_chunks=(8,), paged=True, page_size=16)
    over = _req(0, plen=30, max_new=14)     # 44 tokens: over max_len...
    assert not legacy.fits(over)
    assert paged.fits(over)                 # ...but within 3 pages x 16
    way_over = _req(1, plen=40, max_new=12)     # 52 > 48 addressable
    assert not paged.fits(way_over)
    assert not legacy.fits(_req(2, plen=0))     # empty prompt: both reject
    assert not paged.fits(_req(3, plen=0))


@pytest.mark.overload
def test_backpressure_pool_watermark_sheds_on_arrival(tiny):
    """Pool-pressure shedding: with every page held and the queue at
    capacity, a newly ARRIVED request is shed with a retry hint instead of
    queued; pre-dated trace submissions (arrival in the future) are never
    shed at submit time."""
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32,
                        prefill_chunks=(8,), paged=True, page_size=16,
                        pool_pages=2, page_watermarks=(0.5, 0.25))
    assert eng.submit(_req(0, plen=20, max_new=6))
    for _ in range(4):                      # prefill: slot 0 holds 2/2 pages
        eng.poll()
    assert eng.pool.pressure() >= 0.5
    assert eng.submit(_req(1, plen=8, max_new=2))       # queue below depth
    future = _req(2, plen=8, max_new=2, arrival=eng.now + 100.0)
    assert eng.submit(future) and not future.shed       # not arrived yet
    now_req = _req(3, plen=8, max_new=2, arrival=eng.now)
    assert not eng.submit(now_req)
    assert now_req.shed and now_req.retry_after is not None
    shed_polled = [r for r in eng.poll() if r.shed]
    assert [r.uid for r in shed_polled] == [3]
    eng.drain()                             # clock jumps to uid=2's arrival
    cons = eng.metrics.conservation()
    assert cons["shed"] == 1 and cons["ok"]
