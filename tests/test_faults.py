"""Fault injection / detection / recovery suite (``repro.serving.faults``
plus the engine integration).

Contracts under test:

  * Zero overhead: an engine with ``faults=None`` and an engine with a
    rate-0 plan attached emit IDENTICAL tokens — the fault machinery adds
    nothing to the hot path until an event actually fires.
  * Determinism: the same (params, FaultConfig) always yields the same
    plan, so a fault trace replays exactly across runs and recovery
    settings.
  * Injection -> detection -> repair roundtrips per kind: fingerprint
    probes flag exactly the faulted columns/tiles, and repair restores
    the packed arrays bit-exactly.
  * Conservation: ``submitted == completed + rejected + timed_out`` after
    drain, under fault traces with and without recovery.
  * SLO-aware recovery: recovery-on strictly beats recovery-off on
    corruption-excluded goodput at every nonzero rate.
  * Deadlines: past-deadline requests are cancelled (queued or in-flight),
    freed, and surfaced as ``timed_out`` in both metrics and poll results.

The mesh cases (parity with fault machinery attached, shard-drop reshard)
carry ``@dist`` and need the 8-device leg; everything else runs on one
device.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig, packed_tile_fingerprint
from repro.models import init_params
from repro.models.packing import pack_model_params
from repro.serving import (
    FaultConfig,
    FaultPlan,
    Request,
    ServingEngine,
    drift_detect_rtol,
    make_fault_plan,
)
from repro.serving import faults as faultlib
from repro.serving.faults import FaultEvent

pytestmark = pytest.mark.fault

PACKED = QuantConfig(mode="abfp_packed", tile_width=32, gain=4.0,
                     noise_lsb=0.5)

needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (run under XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 / make test-dist)")


@pytest.fixture(scope="module")
def tinyllama():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return mcfg, params


@pytest.fixture(scope="module")
def packed_params(tinyllama):
    mcfg, params = tinyllama
    return pack_model_params(params, PACKED, mcfg)


def _workload(mcfg, n=10, max_new=6, deadline=None):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=[int(t) for t in
                            rng.integers(1, mcfg.vocab_size, 6)],
                    max_new_tokens=max_new, arrival_time=float(i),
                    deadline=deadline)
            for i in range(n)]


def _tokens(done):
    return {r.uid: tuple(r.generated) for r in done}


# ---------------------------------------------------------------------------
# Plans: determinism, rate semantics, site enumeration
# ---------------------------------------------------------------------------


def test_fault_sites_cover_packed_leaves(packed_params):
    sites = faultlib.fault_sites(packed_params)
    assert sites, "packed model must expose fault sites"
    assert all(s.packed for s in sites)
    assert sites == sorted(sites, key=lambda s: s.path)
    # paths address real leaves
    for s in sites[:3]:
        leaf = faultlib._get_site(packed_params, s.path)
        assert leaf.n_cols == s.n_cols


def test_plan_deterministic_and_bounded(packed_params):
    cfg = FaultConfig(rate=0.05, seed=7, horizon=64)
    p1 = make_fault_plan(packed_params, cfg, tp=4)
    p2 = make_fault_plan(packed_params, cfg, tp=4)
    assert p1.events == p2.events
    assert all(ev.tick < 64 for ev in p1.events)
    drops = [ev for ev in p1.events if ev.kind == "shard_drop"]
    assert len(drops) <= cfg.max_shard_drops
    assert all(0 <= ev.shard < 4 for ev in drops)


def test_plan_rate_zero_empty_and_rate_positive_nonempty(packed_params):
    assert make_fault_plan(packed_params,
                           FaultConfig(rate=0.0)).events == []
    # rate > 0 guarantees at least one event inside the horizon, even when
    # the Bernoulli draw comes up empty (the 0.1%-sweep floor).
    plan = make_fault_plan(packed_params,
                           FaultConfig(rate=1e-6, horizon=32))
    assert len(plan.events) >= 1
    assert plan.events[0].tick < 32


def test_fault_config_validates():
    with pytest.raises(ValueError):
        FaultConfig(kinds=("stuck_col", "bitflip"))
    with pytest.raises(ValueError):
        FaultConfig(rate=1.5)


def test_plan_due_cursor(packed_params):
    plan = FaultPlan([FaultEvent(2, "stuck_col", "a", cols=(0,)),
                      FaultEvent(5, "stuck_col", "b", cols=(1,))],
                     FaultConfig())
    evs, cur = plan.due(tick=3, cursor=0)
    assert [e.path for e in evs] == ["a"] and cur == 1
    evs, cur = plan.due(tick=3, cursor=cur)
    assert evs == [] and cur == 1               # applied exactly once
    evs, cur = plan.due(tick=9, cursor=cur)
    assert [e.path for e in evs] == ["b"] and cur == 2


# ---------------------------------------------------------------------------
# Injection -> detection -> repair roundtrips
# ---------------------------------------------------------------------------


def test_stuck_col_roundtrip(packed_params):
    site = faultlib.fault_sites(packed_params)[0]
    base = faultlib.site_fingerprint(packed_params, site)
    cols = (1, 5)
    bad = faultlib.inject_stuck_cols(packed_params, site.path, cols)
    det = faultlib.detect_site(base, faultlib.site_fingerprint(bad, site))
    assert det.stuck_cols == cols
    assert det.drifted == ()                    # dead cols aren't "drift"
    fixed = faultlib.repair_stuck(bad, packed_params, site.path,
                                  det.stuck_cols)
    leaf0 = faultlib._get_site(packed_params, site.path)
    leaf1 = faultlib._get_site(fixed, site.path)
    assert jnp.array_equal(leaf0.codes, leaf1.codes)
    assert jnp.array_equal(leaf0.scales, leaf1.scales)


def test_scale_drift_roundtrip(packed_params):
    site = faultlib.fault_sites(packed_params)[0]
    base = faultlib.site_fingerprint(packed_params, site)
    tiles = ((0, 3), (site.n_tiles - 1, 7))
    bad = faultlib.inject_scale_drift(packed_params, site.path, tiles,
                                      (1.2, 0.8))
    det = faultlib.detect_site(base, faultlib.site_fingerprint(bad, site))
    assert det.stuck_cols == ()
    assert set(det.drifted) >= set(tiles)       # both drifts flagged
    fixed = faultlib.repair_drift(bad, packed_params, site.path, det.drifted)
    leaf0 = faultlib._get_site(packed_params, site.path)
    leaf1 = faultlib._get_site(fixed, site.path)
    assert jnp.array_equal(leaf0.scales, leaf1.scales)
    assert jnp.array_equal(leaf0.codes, leaf1.codes)


def test_drift_below_tolerance_not_flagged(packed_params):
    site = faultlib.fault_sites(packed_params)[0]
    base = faultlib.site_fingerprint(packed_params, site)
    # Perturb well inside the detection tolerance: must read clean.
    cur = base * (1.0 + 0.1 * drift_detect_rtol())
    assert faultlib.detect_site(base, cur).clean


def test_shard_drop_single_device_kills_sites(packed_params):
    bad = faultlib.inject_shard_drop(packed_params, shard=0, tp=1)
    site = faultlib.fault_sites(packed_params)[0]
    leaf = faultlib._get_site(bad, site.path)
    assert not jnp.any(leaf.codes) and not jnp.any(leaf.scales)


def test_fingerprint_matches_abfp_reduction(packed_params):
    # The probe is exactly sum_i |codes| * delta * scales per (tile, col).
    site = faultlib.fault_sites(packed_params)[0]
    leaf = faultlib._get_site(packed_params, site.path)
    want = packed_tile_fingerprint(leaf)
    want = np.asarray(want.reshape(-1, *want.shape[-2:]).sum(axis=0),
                      np.float32)
    got = faultlib.site_fingerprint(packed_params, site)
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# Engine integration: zero overhead, conservation, recovery wins
# ---------------------------------------------------------------------------


def test_zero_overhead_parity(tinyllama):
    mcfg, params = tinyllama
    base = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                         seed=0)
    out0 = _tokens(base.run(_workload(mcfg)))
    gated = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                          seed=0, faults=FaultConfig(rate=0.0))
    out1 = _tokens(gated.run(_workload(mcfg)))
    assert out0 == out1
    assert gated.metrics.faults["injected"] == 0


@pytest.mark.parametrize("recovery", [True, False], ids=["on", "off"])
def test_conservation_under_faults(tinyllama, recovery):
    mcfg, params = tinyllama
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                        seed=0, faults=FaultConfig(rate=0.05, seed=3,
                                                   horizon=64),
                        recovery=recovery, detect_every=2)
    done = eng.run(_workload(mcfg, n=14))
    cons = eng.metrics.conservation()
    assert cons["ok"], cons
    assert len(done) == 14
    assert eng.metrics.faults["injected"] >= 1


def test_recovery_beats_no_recovery_on_goodput(tinyllama):
    mcfg, params = tinyllama
    good = {}
    for recovery in (True, False):
        eng = ServingEngine(params, mcfg, capacity=4, max_len=64,
                            quant=PACKED, seed=0,
                            faults=FaultConfig(rate=0.02, seed=3,
                                               horizon=64),
                            recovery=recovery, detect_every=2)
        eng.run(_workload(mcfg, n=14))
        assert eng.metrics.conservation()["ok"]
        good[recovery] = eng.metrics.goodput(slo_ttft=100.0) or 0.0
    assert good[True] > good[False]


def test_recovery_counters_and_summary(tinyllama):
    mcfg, params = tinyllama
    plan = FaultPlan([FaultEvent(4, "scale_drift",
                                 faultlib.fault_sites(
                                     pack_model_params(params, PACKED,
                                                       mcfg))[0].path,
                                 tiles=((0, 2),), factors=(1.2,))],
                     FaultConfig(rate=0.01))
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                        seed=0, faults=plan, recovery=True, detect_every=2)
    eng.run(_workload(mcfg))
    s = eng.metrics.summary()
    assert s["faults"]["injected_scale_drift"] == 1
    assert s["faults"]["detected"] >= 1
    assert s["faults"]["tiles_requantized"] >= 1
    assert s["straggler"] is not None           # monitor wired into summary
    assert s["straggler"]["escalation"] in ("log", "reslice", "remesh")


def test_single_device_shard_drop_recovers(tinyllama):
    mcfg, params = tinyllama
    plan = FaultPlan([FaultEvent(5, "shard_drop", "", shard=0)],
                     FaultConfig(rate=0.01))
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                        seed=0, faults=plan, recovery=True, detect_every=2)
    done = eng.run(_workload(mcfg))
    assert eng.metrics.faults["reshards"] == 1
    assert eng.metrics.conservation()["ok"]
    assert len(done) == 10
    assert eng.metrics.summary()["requests"]["requeued"] >= 1


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------


def test_deadline_cancels_inflight_and_frees_slot(tinyllama):
    mcfg, params = tinyllama
    # capacity 1: uid 0 holds the slot past uid 1's patience; uid 0 itself
    # has a deadline it cannot meet (needs ~14 ticks, gets 6).
    reqs = [Request(uid=0, prompt=[3, 5, 7], max_new_tokens=12,
                    arrival_time=0.0, deadline=6.0),
            Request(uid=1, prompt=[2, 4, 6], max_new_tokens=2,
                    arrival_time=0.0)]
    eng = ServingEngine(params, mcfg, capacity=1, max_len=64, quant=PACKED,
                        seed=0)
    for r in reqs:
        eng.submit(r)
    done = eng.drain()
    assert reqs[0].timed_out and reqs[0].done
    assert len(reqs[0].generated) < 12          # cancelled mid-flight
    assert not reqs[1].timed_out
    assert len(reqs[1].generated) == 2          # freed slot was reused
    assert reqs[0] in done and reqs[1] in done  # timeout surfaced via poll
    cons = eng.metrics.conservation()
    assert cons == {"submitted": 2, "completed": 1, "rejected": 0,
                    "timed_out": 1, "shed": 0, "preempted": 0,
                    "resumed": 0, "preempt_ok": True, "ok": True}
    assert eng.metrics.requests[0].timed_out


def test_deadline_expires_queued_request(tinyllama):
    mcfg, params = tinyllama
    # uid 1 can never be admitted before its deadline (capacity 1, uid 0
    # runs ~10 ticks) -> expired from the QUEUE, never admitted.
    reqs = [Request(uid=0, prompt=[3, 5, 7], max_new_tokens=8,
                    arrival_time=0.0),
            Request(uid=1, prompt=[2, 4], max_new_tokens=2,
                    arrival_time=0.0, deadline=3.0)]
    eng = ServingEngine(params, mcfg, capacity=1, max_len=64, quant=PACKED,
                        seed=0)
    for r in reqs:
        eng.submit(r)
    done = eng.drain()
    assert reqs[1].timed_out and reqs[1].generated == []
    assert eng.metrics.requests[1].admit_time is None  # expired in queue
    assert reqs[1] in done
    assert eng.metrics.conservation()["ok"]


def test_deadline_zero_overhead_when_unused(tinyllama):
    mcfg, params = tinyllama
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                        seed=0)
    eng.run(_workload(mcfg, n=6))
    assert not eng._has_deadlines
    assert eng.metrics.conservation()["timed_out"] == 0


# ---------------------------------------------------------------------------
# Mesh cases (8-device leg)
# ---------------------------------------------------------------------------


@pytest.mark.dist
@needs_8
@pytest.mark.parametrize("shape", [(1, 1), (2, 1), (1, 2), (2, 4)])
def test_mesh_parity_with_fault_machinery(tinyllama, shape):
    mcfg, params = tinyllama
    base = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                         seed=0, prefill_chunks=(4, 8))
    out0 = _tokens(base.run(_workload(mcfg)))
    mesh = jax.make_mesh(shape, ("data", "model"))
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                        seed=0, prefill_chunks=(4, 8), mesh=mesh,
                        faults=FaultConfig(rate=0.0))
    out1 = _tokens(eng.run(_workload(mcfg)))
    assert out0 == out1, shape


@pytest.mark.dist
@needs_8
def test_mesh_shard_drop_reshards_and_conserves(tinyllama):
    mcfg, params = tinyllama
    plan = FaultPlan([FaultEvent(6, "shard_drop", "", shard=1)],
                     FaultConfig(rate=0.01))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=PACKED,
                        seed=0, prefill_chunks=(4, 8), mesh=mesh,
                        faults=plan, recovery=True, detect_every=2)
    done = eng.run(_workload(mcfg))
    # (2, 4) loses model bank 1 -> 6 chips -> largest mesh holding tp=4
    # is (1, 4).
    assert tuple(eng.mesh.devices.shape) == (1, 4)
    assert eng.metrics.faults["reshards"] == 1
    assert eng.metrics.conservation()["ok"]
    assert len(done) == 10
