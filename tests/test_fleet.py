"""Heterogeneous fleet serving tests (``make test-fleet``).

Covers the ModelRunner seam end to end: recurrent archs served open-loop
through submit/poll/drain, chunked-prefill bit-identity through the
engine for fixed-state models, the never-preempt guarantee for recurrent
lanes under page-pool pressure, and per-model request conservation on a
three-family multiplexed fleet (decoder-ish enc-dec + two recurrent).
"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import frontends, init_params
from repro.serving import FleetEngine, Request, ServingEngine
from repro.serving.runners import runner_for

pytestmark = pytest.mark.fleet

ARCHS = ("smollm-360m", "whisper-base", "xlstm-350m", "recurrentgemma-2b")


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for a in ARCHS:
        mcfg = smoke_config(a)
        out[a] = (init_params(jax.random.PRNGKey(0), mcfg), mcfg)
    return out


def _feats(mcfg, runner, seed):
    return np.asarray(frontends.audio_stub_features(
        jax.random.PRNGKey(seed), 1, runner.enc_len, mcfg.d_model)[0],
        np.float32)


def _reqs(mcfg, n, *, prompt_len=4, max_new=4, model=None, features=None,
          uid0=0, arrivals=None):
    rng = np.random.default_rng(uid0 + 1)
    return [Request(
        uid=uid0 + i,
        prompt=rng.integers(1, mcfg.vocab_size, prompt_len).tolist(),
        max_new_tokens=max_new, model=model, features=features,
        arrival_time=None if arrivals is None else float(arrivals[i]))
        for i in range(n)]


# -- recurrent archs through the open-loop engine ----------------------------

@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-2b"])
def test_recurrent_open_loop_submit_poll_drain(zoo, arch):
    params, mcfg = zoo[arch]
    eng = ServingEngine(params, mcfg, capacity=2, max_len=32)
    reqs = _reqs(mcfg, 5, arrivals=[0, 0, 1, 3, 6])
    for r in reqs:
        assert eng.submit(r)
    done = eng.drain()
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)
    assert eng.metrics.conservation()["ok"]


@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-2b"])
def test_recurrent_chunked_prefill_matches_token_by_token(zoo, arch):
    """Float mode: bucketed chunked prefill through the engine must emit
    bit-identical greedy tokens to the legacy one-prompt-token-per-tick
    path (recurrent folds + ring caches advance identically)."""
    params, mcfg = zoo[arch]

    def serve(chunked):
        eng = ServingEngine(params, mcfg, capacity=2, max_len=32, seed=0,
                            chunked=chunked, prefill_chunks=(4, 8))
        # Prompt lengths straddle the (4, 8) buckets, incl. an exact hit.
        reqs = [Request(uid=i, prompt=list(range(2, 2 + n)),
                        max_new_tokens=4)
                for i, n in enumerate((3, 4, 9))]
        eng.run(reqs)
        return [r.generated for r in reqs]

    assert serve(chunked=True) == serve(chunked=False)


def test_recurrent_admissible_at_any_length(zoo):
    """Fixed-state slots have no max_len-bound KV: a request whose
    prompt + max_new exceeds max_len is still admissible."""
    params, mcfg = zoo["xlstm-350m"]
    eng = ServingEngine(params, mcfg, capacity=1, max_len=16)
    long_req = Request(uid=0, prompt=list(range(1, 25)), max_new_tokens=8)
    assert eng.fits(long_req)
    assert eng.submit(long_req)
    eng.drain()
    assert len(long_req.generated) == 8


# -- fleet construction / routing --------------------------------------------

def test_models_kwarg_builds_fleet(zoo):
    eng = ServingEngine(models={"a": zoo["smollm-360m"],
                                "b": zoo["xlstm-350m"]}, capacity=4)
    assert isinstance(eng, FleetEngine)
    assert {n: l.capacity for n, l in eng.lanes.items()} == {"a": 2, "b": 2}


def test_model_split_overrides(zoo):
    eng = ServingEngine(models={"a": zoo["smollm-360m"],
                                "b": zoo["xlstm-350m"]},
                        capacity=6, model_split={"a": 4})
    assert eng.lanes["a"].capacity == 4
    assert eng.lanes["b"].capacity == 2
    with pytest.raises(KeyError):
        ServingEngine(models={"a": zoo["smollm-360m"]},
                      capacity=2, model_split={"zzz": 1})


def test_routing_unknown_model_raises(zoo):
    eng = ServingEngine(models={"a": zoo["smollm-360m"],
                                "b": zoo["xlstm-350m"]}, capacity=4)
    with pytest.raises(KeyError, match="unknown model"):
        eng.submit(Request(uid=0, prompt=[1], max_new_tokens=1,
                           model="zzz"))
    with pytest.raises(KeyError, match="no model routing key"):
        eng.submit(Request(uid=1, prompt=[1], max_new_tokens=1))


def test_single_lane_fleet_defaults_routing(zoo):
    eng = ServingEngine(models={"only": zoo["smollm-360m"]}, capacity=2)
    req = Request(uid=0, prompt=[1, 2], max_new_tokens=2)
    assert eng.submit(req)
    eng.drain()
    assert len(req.generated) == 2


# -- recurrent lanes never preempt under pool pressure ------------------------

def test_fixed_state_lane_never_preempted(zoo):
    """A paged fleet puts ONLY pageable lanes on the pool: the recurrent
    lane runs unpaged (no pool, no preemption machinery at all), so pool
    pressure on the decoder lane can never evict recurrent slots."""
    eng = ServingEngine(
        models={"dec": zoo["smollm-360m"], "rec": zoo["xlstm-350m"]},
        capacity=6, model_split={"dec": 4}, max_len=32,
        paged=True, page_size=8, pool_pages=6)
    # Structural guarantees first: pool exists only for the decoder lane.
    assert eng.lanes["dec"].paged and eng.lanes["dec"].pool is not None
    assert not eng.lanes["rec"].paged and eng.lanes["rec"].pool is None
    assert not eng.lanes["rec"].preemption

    # 4 decoder slots x 16-token requests (2 pages each) against a 6-page
    # pool: growth must preempt.  The recurrent lane serves concurrently.
    mcfg_d = zoo["smollm-360m"][1]
    mcfg_r = zoo["xlstm-350m"][1]
    reqs = (_reqs(mcfg_d, 8, prompt_len=8, max_new=8, model="dec",
                  arrivals=[0] * 8)
            + _reqs(mcfg_r, 4, prompt_len=8, max_new=8, model="rec",
                    uid0=100, arrivals=[0] * 4))
    for r in reqs:
        eng.submit(r)
    done = eng.drain()
    cons = eng.conservation()
    assert cons["dec"]["ok"] and cons["dec"]["preempt_ok"]
    assert cons["rec"]["ok"]
    assert cons["dec"]["preempted"] > 0      # pressure was real
    assert cons["rec"]["preempted"] == 0     # fixed state: never evicted
    assert len(done) == len(reqs)
    assert all(len(r.generated) == 8 for r in reqs if r.model == "rec")


# -- three-family multiplexed fleet ------------------------------------------

def test_three_model_fleet_conservation(zoo):
    names = ("whisper-base", "xlstm-350m", "recurrentgemma-2b")
    runners = {n: runner_for(zoo[n][1]) for n in names}
    eng = ServingEngine(models={n: zoo[n] for n in names}, capacity=6,
                        max_len=32)
    reqs = []
    for i in range(9):
        name = names[i % 3]
        feats = (_feats(zoo[name][1], runners[name], i)
                 if runners[name].needs_admission else None)
        reqs += _reqs(zoo[name][1], 1, model=name, features=feats, uid0=i,
                      arrivals=[i * 0.5])
    for r in reqs:
        assert eng.submit(r)
    done = eng.drain()
    assert len(done) == 9
    cons = eng.conservation()
    for n in names:
        assert cons[n]["submitted"] == 3, (n, cons[n])
        assert cons[n]["completed"] == 3, (n, cons[n])
        assert cons[n]["ok"], (n, cons[n])
    assert eng.ticks == sum(l.ticks for l in eng.lanes.values())
    # Per-model metrics are isolated: each lane saw only its own requests.
    summ = eng.summary()
    assert all(summ[n]["requests"]["finished"] == 3 for n in names)
