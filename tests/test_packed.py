"""Packed ABFP weights: pack-once correctness, bit-identity, and plumbing.

The packed serving path must be indistinguishable (to the bit) from the
quantize-every-call kernel: ``pack_abfp_weight`` runs the identical weight
quantization (bf16-rounded max-abs scales, round-half-even int codes) ahead
of time, and ``abfp_matmul_packed_pallas`` shares the ADC constant, noise
hash, salt layout, and accumulation order with ``abfp_matmul_pallas``.
Against the einsum oracle (which contracts all tiles in one einsum) the
match is to f32 accumulation-order ULP, same as the unpacked kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abfp import (
    QuantConfig,
    dequantize_packed,
    pack_abfp_weight,
    quant_delta,
    quantize_weight_tiles,
)
from repro.kernels.abfp_matmul import (
    abfp_matmul_packed_pallas,
    abfp_matmul_pallas,
    auto_bm,
)
from repro.kernels.ops import dense, dense_packed
from repro.kernels.ref import abfp_matmul_ref

TOL = dict(rtol=2e-5, atol=2e-5)

# K/N deliberately not multiples of tile or block sizes where noted.
SHAPES = [(16, 256, 64), (8, 200, 48), (130, 500, 136)]


def _rand(mkn, seed=0, dtype=jnp.float32):
    m, k, n = mkn
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = (jax.random.normal(kx, (m, k)) * 0.7).astype(dtype)
    w = (jax.random.laplace(kw, (k, n)) * 0.08).astype(dtype)
    return x, w


# ---------------------------------------------------------------------------
# Pack-time quantization == run-time quantization, to the bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [8, 32, 128])
@pytest.mark.parametrize("k,n", [(256, 64), (200, 48), (500, 136)])
def test_pack_matches_runtime_quantization(tile, k, n):
    cfg = QuantConfig(tile_width=tile, out_dtype=jnp.float32)
    _, w = _rand((1, k, n))
    pw = pack_abfp_weight(w, cfg)
    w_q, s_w = quantize_weight_tiles(w, cfg)       # (T, n, N), (T, N)
    assert pw.codes.dtype == jnp.int8
    assert pw.scales.dtype == jnp.bfloat16
    # N is lane-aligned at pack time; the logical columns match the
    # run-time quantization to the bit, the padding is all-zero.
    assert pw.n_padded % 128 == 0 and pw.n_padded >= n
    codes = np.asarray(pw.codes, np.float32)
    np.testing.assert_array_equal(
        codes[:, :n].reshape(w_q.shape), np.asarray(w_q, np.float32))
    assert not codes[:, n:].any()
    scales = np.asarray(pw.scales, np.float32)
    np.testing.assert_array_equal(scales[:, :n], np.asarray(s_w, np.float32))
    assert not scales[:, n:].any()
    # Padding metadata round-trip: logical shape survives pack/dequantize.
    assert pw.shape == (k, n)
    assert pw.kp % tile == 0 and pw.kp >= k
    w_deq = dequantize_packed(pw)
    assert w_deq.shape == (k, n)
    lattice = (np.asarray(w_q, np.float32)
               * quant_delta(cfg.bits_w)
               * np.asarray(s_w, np.float32)[:, None, :]).reshape(-1, n)[:k]
    np.testing.assert_array_equal(np.asarray(w_deq), lattice)


def test_pack_rejects_codes_wider_than_int8():
    cfg = QuantConfig(tile_width=32, bits_w=10)
    _, w = _rand((1, 64, 16))
    with pytest.raises(ValueError, match="int8"):
        pack_abfp_weight(w, cfg)


def test_pack_rejects_percentile_scales():
    cfg = QuantConfig(tile_width=32, scale_percentile=99.0)
    _, w = _rand((1, 64, 16))
    with pytest.raises(ValueError, match="max-abs"):
        pack_abfp_weight(w, cfg)


def test_packed_kernel_rejects_scale_dtype_mismatch():
    cfg = QuantConfig(tile_width=32, out_dtype=jnp.float32)
    x, w = _rand((2, 96, 16))
    pw = pack_abfp_weight(w, cfg)
    with pytest.raises(ValueError, match="scale_dtype"):
        abfp_matmul_packed_pallas(
            x, pw, cfg.replace(scale_dtype=jnp.float32))


def test_pack_leading_axes_and_indexing():
    """Stacked (NG, K, N) params: pack keeps leading axes; scan/index work."""
    cfg = QuantConfig(tile_width=32, out_dtype=jnp.float32)
    _, w = _rand((1, 96, 40))
    ws = jnp.stack([w, 2.0 * w, -w])
    pws = pack_abfp_weight(ws, cfg)
    assert pws.codes.shape[0] == 3 and pws.scales.shape[0] == 3
    one = pack_abfp_weight(2.0 * w, cfg)
    np.testing.assert_array_equal(np.asarray(pws[1].codes), np.asarray(one.codes))
    np.testing.assert_array_equal(
        np.asarray(pws[1].scales, np.float32),
        np.asarray(one.scales, np.float32))
    x, _ = _rand((4, 96, 40))
    y_direct = abfp_matmul_packed_pallas(x, one, cfg)
    _, ys = jax.lax.scan(
        lambda c, p: (c, abfp_matmul_packed_pallas(x, p, cfg)), 0, pws)
    np.testing.assert_array_equal(np.asarray(ys[1]), np.asarray(y_direct))


# ---------------------------------------------------------------------------
# Packed kernel == unpacked kernel, to the bit (incl. noise seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [8, 32, 128])
@pytest.mark.parametrize("mkn", SHAPES)
def test_packed_bit_identical_to_unpacked(tile, mkn):
    cfg = QuantConfig(tile_width=tile, gain=4.0, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    x, w = _rand(mkn)
    pw = pack_abfp_weight(w, cfg)
    y_p = abfp_matmul_packed_pallas(x, pw, cfg)
    y_u = abfp_matmul_pallas(x, w, cfg)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


@pytest.mark.parametrize("tile", [8, 32, 128])
def test_packed_matches_oracle(tile):
    cfg = QuantConfig(tile_width=tile, gain=8.0, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    for mkn in SHAPES:
        x, w = _rand(mkn, seed=2)
        y_p = abfp_matmul_packed_pallas(x, pack_abfp_weight(w, cfg), cfg)
        y_r = abfp_matmul_ref(x, w, cfg)
        np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), **TOL)


@pytest.mark.parametrize("seed", [0, 1, 17])
def test_packed_noise_bit_identical_to_unpacked(seed):
    """Same hash PRNG, same salts: noise-on outputs match to the bit."""
    cfg = QuantConfig(tile_width=32, gain=8.0, noise_lsb=0.5,
                      out_dtype=jnp.float32)
    x, w = _rand((64, 500, 96), seed=3)
    pw = pack_abfp_weight(w, cfg)
    s = jnp.array([seed], jnp.int32)
    y_p = abfp_matmul_packed_pallas(x, pw, cfg, s)
    y_u = abfp_matmul_pallas(x, w, cfg, s)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))
    # distinct seeds give distinct noise
    y_p2 = abfp_matmul_packed_pallas(x, pw, cfg, jnp.array([seed + 1], jnp.int32))
    assert float(jnp.abs(y_p2 - y_p).max()) > 0.0


# ---------------------------------------------------------------------------
# Decode-shape specialization
# ---------------------------------------------------------------------------


def test_auto_bm_decode_blocks():
    assert auto_bm(1) == 8
    assert auto_bm(8) == 8
    assert auto_bm(9) == 16
    assert auto_bm(100) == 104
    assert auto_bm(128) == 128
    assert auto_bm(4096) == 128


@pytest.mark.parametrize("m", [1, 2, 8])
def test_packed_decode_shapes(m):
    cfg = QuantConfig(tile_width=128, gain=4.0, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    x, w = _rand((m, 512, 256), seed=4)
    pw = pack_abfp_weight(w, cfg)
    y_p = abfp_matmul_packed_pallas(x, pw, cfg)       # auto bm = 8
    y_r = abfp_matmul_ref(x, w, cfg)
    assert y_p.shape == (m, 256)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), **TOL)
    # Explicit large block gives the same values (block-shape invariance).
    y_big = abfp_matmul_packed_pallas(x, pw, cfg, bm=128)
    np.testing.assert_allclose(np.asarray(y_big), np.asarray(y_r), **TOL)


def test_packed_batched_input():
    cfg = QuantConfig(tile_width=32, noise_lsb=0.0, out_dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 160))
    w = jax.random.normal(jax.random.PRNGKey(1), (160, 48)) * 0.1
    y_p = abfp_matmul_packed_pallas(x, pack_abfp_weight(w, cfg), cfg)
    y_u = abfp_matmul_pallas(x, w, cfg)
    assert y_p.shape == (2, 5, 48)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


def test_packed_rejects_mismatched_config():
    cfg = QuantConfig(tile_width=32, out_dtype=jnp.float32)
    _, w = _rand((1, 96, 16))
    pw = pack_abfp_weight(w, cfg)
    with pytest.raises(ValueError, match="does not match"):
        abfp_matmul_packed_pallas(jnp.ones((2, 96)), pw,
                                  cfg.replace(tile_width=8))


# ---------------------------------------------------------------------------
# Property tests: pack -> unpack round-trip at arbitrary tile widths
# ---------------------------------------------------------------------------
#
# The parametrized suites above pin tile widths to the hardware-typical
# 8/32/128; nothing guaranteed the pack/dequantize pair for OTHER widths
# (including non-powers-of-two) or for K/N deliberately off every block
# multiple.  These are seeded-random property checks of the round-trip
# invariants; shapes are drawn per seed so each run covers a spread of
# (tile, K, N) combinations without hypothesis.


def _roundtrip_case(seed):
    rng = np.random.default_rng(seed)
    tile = int(rng.choice([3, 5, 8, 12, 24, 32, 48, 96, 128, 160]))
    k = int(rng.integers(1, 6) * tile + rng.integers(1, tile + 1))  # off-tile
    n = int(rng.integers(1, 300))
    bits = int(rng.choice([4, 6, 8]))
    return tile, k, n, bits


@pytest.mark.parametrize("seed", range(12))
def test_pack_roundtrip_random_tiles_and_ragged_shapes(seed):
    """Round-trip invariants for random (tile, K, N, bits_w):

      * dequantize(pack(w)) lands exactly on the quantize_weight_tiles
        lattice (codes * delta_w * scales), at the original (K, N);
      * codes stay within [-L_w, +L_w];
      * K rows beyond k and lane-padding columns are all-zero codes AND
        all-zero scales (they must contribute exactly 0 to any matmul).
    """
    tile, k, n, bits = _roundtrip_case(seed)
    cfg = QuantConfig(tile_width=tile, bits_w=bits, out_dtype=jnp.float32)
    w = np.asarray(
        jax.random.laplace(jax.random.PRNGKey(seed), (k, n)) * 0.3,
        np.float32)
    pw = pack_abfp_weight(jnp.asarray(w), cfg)

    assert pw.shape == (k, n)
    assert pw.kp == -(-k // tile) * tile
    assert pw.n_padded == -(-n // 128) * 128
    lvl = 2 ** (bits - 1) - 1
    codes = np.asarray(pw.codes, np.float32)
    assert np.abs(codes).max() <= lvl
    assert not codes[k:].any() and not codes[:, n:].any()
    scales = np.asarray(pw.scales, np.float32)
    assert not scales[:, n:].any()

    w_q, s_w = quantize_weight_tiles(jnp.asarray(w), cfg)
    lattice = (np.asarray(w_q, np.float32) * quant_delta(bits)
               * np.asarray(s_w, np.float32)[:, None, :]).reshape(-1, n)[:k]
    np.testing.assert_array_equal(np.asarray(dequantize_packed(pw)), lattice)


@pytest.mark.parametrize("seed", range(6))
def test_pack_roundtrip_error_bounded(seed):
    """|dequantize(pack(w)) - w| <= per-element quantization budget:
    half a weight bin times the (bf16-rounded) tile scale."""
    tile, k, n, bits = _roundtrip_case(seed + 100)
    cfg = QuantConfig(tile_width=tile, bits_w=bits, out_dtype=jnp.float32)
    w = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed + 100), (k, n)) * 0.5,
        np.float32)
    pw = pack_abfp_weight(jnp.asarray(w), cfg)
    w_deq = np.asarray(dequantize_packed(pw))
    # Per (tile, col) scale, bf16-rounded down by at most 1 part in 256:
    # elements quantize within half a bin of that scale (plus the clamp
    # slack when bf16(max) < max, bounded by the same factor).
    t = pw.num_tiles
    w_pad = np.zeros((t * tile, n), np.float32)
    w_pad[:k] = w
    s = np.abs(w_pad.reshape(t, tile, n)).max(axis=1)          # (T, N)
    bound = (s * (0.5 * quant_delta(bits) + 1 / 256.0) + 1e-7)[:, None, :]
    err = np.abs(w_deq - w).reshape(-1, n)
    err_t = np.zeros((t * tile, n), np.float32)
    err_t[:k] = err
    assert (err_t.reshape(t, tile, n) <= bound).all()


def test_packed_param_bytes_counts_scales():
    """Regression: the HBM accounting must include the bf16 scale planes,
    not just the int8 codes (scales are T/K of the code bytes at bf16 —
    at tile 8 they are a QUARTER of the packed footprint)."""
    from repro.models.packing import packed_param_bytes

    cfg = QuantConfig(tile_width=8, out_dtype=jnp.float32)
    _, w = _rand((1, 256, 128))
    pw = pack_abfp_weight(w, cfg)
    expect = (pw.codes.size * pw.codes.dtype.itemsize
              + pw.scales.size * pw.scales.dtype.itemsize)
    assert pw.nbytes() == expect
    assert packed_param_bytes({"wq": pw}) == expect
    # scale bytes are material: (T=32, 128) bf16 vs (256, 128) int8 codes
    assert pw.scales.size * pw.scales.dtype.itemsize == expect // 5
    # mixed tree: float leaves counted at their own dtype width
    extra = jnp.zeros((16, 4), jnp.float32)
    assert packed_param_bytes({"wq": pw, "norm": extra}) \
        == expect + extra.size * 4


# ---------------------------------------------------------------------------
# Dispatch + STE
# ---------------------------------------------------------------------------


def test_dense_abfp_packed_mode_and_ste():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96), dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 32)) * 0.1
    cfg_k = QuantConfig(mode="abfp_kernel", tile_width=32, noise_lsb=0.0,
                        out_dtype=jnp.float32)
    cfg_p = cfg_k.replace(mode="abfp_packed")
    np.testing.assert_array_equal(
        np.asarray(dense(x, w, cfg_p)), np.asarray(dense(x, w, cfg_k)))
    # STE (Eq. 8): pack-on-the-fly mode keeps plain-matmul gradients.
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(dense(x, w, cfg_p).astype(jnp.float32)),
        argnums=(0, 1))(x, w)
    np.testing.assert_allclose(
        np.asarray(gx),
        np.asarray(jnp.sum(w, axis=1)[None, :] * jnp.ones_like(x)), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(gw),
        np.asarray(jnp.sum(x, axis=0)[:, None] * jnp.ones_like(w)), rtol=1e-4)


def test_dense_packed_prepacked_ste():
    """Pre-packed weights: dx flows through the dequantized lattice."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96), dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 32)) * 0.1
    cfg = QuantConfig(mode="abfp_packed", tile_width=32, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    pw = pack_abfp_weight(w, cfg)
    y = dense_packed(x, pw, cfg)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(abfp_matmul_packed_pallas(x, pw, cfg)))
    gx = jax.grad(lambda x: jnp.sum(dense_packed(x, pw, cfg)))(x)
    w_deq = dequantize_packed(pw)
    np.testing.assert_allclose(
        np.asarray(gx),
        np.asarray(jnp.matmul(jnp.ones((4, 32)), w_deq.T)), rtol=1e-5)


# ---------------------------------------------------------------------------
# Whole-model packing + packed serving tick
# ---------------------------------------------------------------------------


def test_pack_model_params_and_decode_bit_identical():
    from repro.configs import smoke_config
    from repro.core.abfp import PackedWeight
    from repro.models import (
        decode_step,
        init_decode_state,
        init_params,
        pack_model_params,
        packed_param_bytes,
    )
    from repro.models.layers import Numerics

    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    cfg_k = QuantConfig(mode="abfp_kernel", tile_width=32, noise_lsb=0.0)
    cfg_p = cfg_k.replace(mode="abfp_packed")
    packed = pack_model_params(params, cfg_p, mcfg)

    leaves = jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedWeight))
    n_packed = sum(isinstance(v, PackedWeight) for v in leaves)
    assert n_packed > 0
    # int8 codes shrink the dense weights vs the float tree.
    assert packed_param_bytes(packed) < packed_param_bytes(params)

    token = jnp.array([3, 5], jnp.int32)
    st_k = init_decode_state(mcfg, 2, 16)
    st_p = init_decode_state(mcfg, 2, 16)
    logits_k, _ = decode_step(params, st_k, token, mcfg, Numerics(cfg_k))
    logits_p, _ = decode_step(packed, st_p, token, mcfg, Numerics(cfg_p))
    np.testing.assert_array_equal(np.asarray(logits_k), np.asarray(logits_p))


def test_serving_engine_packed_mode():
    from repro.configs import smoke_config
    from repro.core.abfp import PackedWeight
    from repro.models import init_params
    from repro.serving import Request, ServingEngine

    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    outs = {}
    for mode in ("abfp_kernel", "abfp_packed"):
        q = QuantConfig(mode=mode, tile_width=32, gain=4.0, noise_lsb=0.0)
        eng = ServingEngine(params, mcfg, capacity=2, max_len=32, quant=q)
        if mode == "abfp_packed":
            assert any(isinstance(v, PackedWeight)
                       for v in jax.tree_util.tree_leaves(
                           eng.params,
                           is_leaf=lambda x: isinstance(x, PackedWeight)))
        reqs = [Request(uid=i, prompt=[2 + i, 7, 11], max_new_tokens=3)
                for i in range(2)]
        done = eng.run(reqs)
        outs[mode] = {r.uid: r.generated for r in done}
    assert outs["abfp_kernel"] == outs["abfp_packed"]
