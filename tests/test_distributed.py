"""Distribution tests: sharding rules, ZeRO-1, HLO collective parsing, and a
multi-device MoE equivalence check (8 placeholder CPU devices, subprocess)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.distributed.sharding import (
    MODEL_AXIS,
    abfp_param_spec_tree,
    param_spec_tree,
    validate_spec,
    zero1_spec,
)
from repro.launch.hlo_analysis import collective_stats, roofline_terms
from repro.models import init_params


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


def test_param_spec_rules():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    specs = param_spec_tree(params)
    g = specs["groups"][0]
    # Stacked leaves get a leading None (scan axis).
    assert g["attn"]["wq"] == P(None, None, MODEL_AXIS)
    assert g["attn"]["wo"] == P(None, MODEL_AXIS, None)
    assert g["mlp"]["wi"] == P(None, None, MODEL_AXIS)
    assert g["mlp"]["wo"] == P(None, MODEL_AXIS, None)
    assert g["norm1"]["scale"] == P(None, None)      # replicated
    assert specs["embed"] == P(MODEL_AXIS, None)
    assert specs["lm_head"] == P(None, MODEL_AXIS)


def test_abfp_spec_demotes_row_parallel():
    """ABFP tiles must not straddle shards: K-axis sharding demoted."""
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    specs = abfp_param_spec_tree(params)
    g = specs["groups"][0]
    assert g["attn"]["wq"] == P(None, None, MODEL_AXIS)   # col-parallel kept
    assert g["attn"]["wo"] == P(None, None, None)         # row demoted
    assert g["mlp"]["wo"] == P(None, None, None)


def test_moe_expert_parallel_specs():
    mcfg = smoke_config("granite-moe-1b-a400m")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    specs = param_spec_tree(params)
    g = specs["groups"][0]
    assert g["moe"]["wi"] == P(None, MODEL_AXIS, None, None)  # experts over TP
    assert g["moe"]["router"] == P(None, None, None)


def test_validate_spec_drops_indivisible():
    mesh = _FakeMesh()
    assert validate_spec(P("model", None), (51865, 512), mesh) == P(None, None)
    assert validate_spec(P("model", None), (512, 64), mesh) == P("model", None)
    assert validate_spec(P(("data",), None), (1, 8), mesh) == P(None, None)
    assert validate_spec(P(("data", "model"), None), (8, 8), mesh) == \
        P(("data", "model"), None)


def test_zero1_spec_picks_largest_divisible_axis():
    mesh = _FakeMesh()
    # (K=512, N=64) sharded (None, model): data goes on dim0 (512 % 4 == 0).
    assert zero1_spec(P(None, "model"), (512, 64), mesh) == P("data", "model")
    # nothing divisible: unchanged
    assert zero1_spec(P(None,), (7,), mesh) == P(None,)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------


_HLO = textwrap.dedent("""
ENTRY %main.1 (p: f32[256,1024]) -> f32[256,1024] {
  %param.1 = f32[256,1024]{1,0} parameter(0)
  %all-reduce.1 = f32[256,1024]{1,0} all-reduce(%param.1), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[512,1024]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}
  %reduce-scatter.3 = f32[64,1024]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %collective-permute.4 = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  ROOT %add.5 = f32[256,1024]{1,0} add(%param.1, %param.1)
}
""")


def test_collective_stats_parses_ops_and_bytes():
    stats = collective_stats(_HLO)
    assert stats["all-reduce"]["count"] == 1
    # all-reduce: 2 * size * (g-1)/g; size = 256*1024*4, g=4
    assert stats["all-reduce"]["bytes"] == int(2 * 256 * 1024 * 4 * 3 / 4)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == int(512 * 1024 * 2 * 1 / 2)
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["collective-permute"]["bytes"] == 8 * 8 * 4
    assert stats["total"]["count"] == 4


def test_roofline_terms_bottleneck():
    t = roofline_terms(1e12, 1e9, 1e6, chips=256,
                       peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    assert t["bottleneck"] == "compute_s"
    t2 = roofline_terms(1e9, 1e9, 1e9, chips=256,
                        peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    assert t2["bottleneck"] == "collective_s"


# ---------------------------------------------------------------------------
# Multi-device semantics (subprocess: 8 placeholder CPU devices)
# ---------------------------------------------------------------------------


_MOE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.models.layers import Numerics
from repro.models import moe as moe_lib

# capacity_factor high enough that no (token, expert) pair is dropped: the
# expert-parallel path must then match the single-shard path exactly.
mcfg = dataclasses.replace(smoke_config("granite-moe-1b-a400m"),
                           capacity_factor=8.0)
key = jax.random.PRNGKey(0)
params = moe_lib.init_moe(key, mcfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, mcfg.d_model))
nx = Numerics(QuantConfig(mode="float"))

y_local, aux_local = moe_lib.moe_block(params, x, mcfg, nx)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    y_sh, aux_sh = jax.jit(
        lambda p, x: moe_lib.moe_block_sharded(p, x, mcfg, nx, mesh)
    )(params, x)

np.testing.assert_allclose(np.asarray(y_local, np.float32),
                           np.asarray(y_sh, np.float32), rtol=2e-2, atol=2e-2)
# aux is E*sum(density*p_mean): a nonlinear statistic, so the mean of
# per-data-shard values differs from the whole-batch value by O(1/T_loc) —
# ~1% at this smoke scale, vanishing at production token counts.
np.testing.assert_allclose(float(aux_local), float(aux_sh), rtol=5e-2)

# At the production capacity factor (1.25), GShard-style dropping may zero a
# small fraction of (token, expert) contributions under load imbalance.
mcfg2 = dataclasses.replace(mcfg, capacity_factor=1.25)
with mesh:
    y_dp, _ = jax.jit(
        lambda p, x: moe_lib.moe_block_sharded(p, x, mcfg2, nx, mesh)
    )(params, x)
frac = float(jnp.mean(jnp.any(
    jnp.abs(y_dp - y_sh) > 0.05 * (1 + jnp.abs(y_sh)), axis=-1)))
assert frac < 0.25, f"too many dropped tokens: {frac}"
print("MOE_SHARDED_OK")
"""


@pytest.mark.slow
def test_moe_sharded_matches_local():
    """Expert-parallel shard_map MoE == single-shard MoE (8 fake devices)."""
    r = subprocess.run([sys.executable, "-c", _MOE_SCRIPT],
                       capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo")
    assert "MOE_SHARDED_OK" in r.stdout, r.stdout + r.stderr


_SHARDED_FWD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.distributed.sharding import param_spec_tree, batch_spec
from repro.models import forward, init_params

mcfg = smoke_config("tinyllama-1.1b")
params = init_params(jax.random.PRNGKey(0), mcfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, mcfg.vocab_size)

logits_1d, _ = jax.jit(lambda p, t: forward(p, t, mcfg))(params, toks)

mesh = jax.make_mesh((2, 4), ("data", "model"))
ps = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  param_spec_tree(params, mesh),
                  is_leaf=lambda x: isinstance(x, P))
sp = jax.device_put(params, ps)
st = jax.device_put(toks, NamedSharding(mesh, batch_spec(mesh, toks.shape)))
with mesh:
    logits_8d, _ = jax.jit(lambda p, t: forward(p, t, mcfg))(sp, st)

np.testing.assert_allclose(np.asarray(logits_1d), np.asarray(logits_8d),
                           rtol=2e-2, atol=2e-2)
print("SHARDED_FWD_OK")
"""


@pytest.mark.slow
def test_sharded_forward_matches_single_device():
    """GSPMD-sharded forward == single-device forward (8 fake devices)."""
    r = subprocess.run([sys.executable, "-c", _SHARDED_FWD_SCRIPT],
                       capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo")
    assert "SHARDED_FWD_OK" in r.stdout, r.stdout + r.stderr
