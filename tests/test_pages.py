"""Paged KV pool + overload-robust serving tests.

Three layers:
  * PagePool allocator unit tests (host-only, no jax compute): free-list /
    refcount / CoW / LRU-prefix-cache invariants.
  * Paged-engine parity: float-mode decode through the paged attention
    path is BIT-IDENTICAL to the unpaged engine (the page-table gather
    feeds the same attention cores over the same values); kv_quant rides
    the same argument, abfp_packed is exercised for liveness.
  * Overload behavior: preemption with bit-identical recompute resume,
    priority page claims, admission backpressure (shedding + retry-after),
    degraded modes with hysteresis, and per-tenant quotas.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models import init_params
from repro.serving import (
    PagePool,
    Request,
    ServingEngine,
    pages_needed,
    plan_chunk,
    prefix_key,
)


# ---------------------------------------------------------------------------
# PagePool allocator (host-only)
# ---------------------------------------------------------------------------

def test_pages_needed_ceil_div():
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert pages_needed(0, 16) == 0


def test_alloc_release_roundtrip():
    pool = PagePool(4, 16)
    got = pool.alloc(3, "a")
    assert got is not None and len(set(got)) == 3
    assert pool.stats().free == 1 and pool.tenant_held("a") == 3
    pool.release(got, "a")
    assert pool.stats().free == 4 and pool.tenant_held("a") == 0
    pool.check()


def test_alloc_all_or_nothing():
    pool = PagePool(4, 16)
    assert pool.alloc(4) is not None
    assert pool.alloc(1) is None            # dry, nothing cached to evict
    pool.check()


def test_share_then_release_keeps_page_until_last_ref():
    pool = PagePool(2, 16)
    [p] = pool.alloc(1, "a")
    pool.share([p], "b")
    pool.release([p], "a")
    assert pool.ref[p] == 1                 # b still holds it
    pool.release([p], "b")
    assert pool.stats().free == 2
    pool.check()


def test_cow_exclusive_is_noop_shared_splits():
    pool = PagePool(3, 16)
    [p] = pool.alloc(1, "a")
    assert pool.cow(p, "a") == p            # exclusive: write in place
    pool.share([p], "b")
    q = pool.cow(p, "b")                    # shared: b gets a private copy
    assert q is not None and q != p
    assert pool.ref[p] == 1 and pool.ref[q] == 1
    assert pool.stats().cow_copies == 1
    pool.check()


def test_cow_pool_exhausted_returns_none():
    pool = PagePool(2, 16)
    pages = pool.alloc(2, "a")
    pool.share([pages[0]], "b")
    assert pool.cow(pages[0], "b") is None  # no page left for the copy
    pool.check()


def test_prefix_cache_register_lookup_and_lru_eviction():
    pool = PagePool(3, 4)
    keys = [prefix_key(None, [i, i, i, i]) for i in range(3)]
    pages = [pool.alloc(1)[0] for _ in range(3)]
    for k, p in zip(keys, pages):
        pool.register(k, p)
        pool.release([p])                   # cache-only now
    assert pool.stats().cached == 3 and pool.stats().free == 0
    pool.lookup(keys[0])                    # touch: keys[0] becomes MRU
    got = pool.alloc(2)                     # must evict the 2 LRU entries
    assert got is not None
    assert pool.lookup(keys[0]) is not None     # survivor
    assert pool.lookup(keys[1]) is None and pool.lookup(keys[2]) is None
    assert pool.stats().prefix_evictions == 2
    pool.check()


def test_prefix_key_chains_commit_to_whole_prefix():
    a = prefix_key(None, [1, 2])
    assert prefix_key(a, [3, 4]) != prefix_key(prefix_key(None, [9, 9]),
                                               [3, 4])
    assert prefix_key(a, [3, 4]) == prefix_key(prefix_key(None, [1, 2]),
                                               [3, 4])


def test_plan_chunk_write_range_and_growth():
    # slot at 10 tokens, 2 pages held (PS 8): appending 7 crosses into a
    # third page -> 1 extra, writes touch held pages 1 (and would touch 2).
    extra, writes = plan_chunk(10, 7, [4, 5], 8)
    assert extra == 1 and writes == [1]
    extra, writes = plan_chunk(0, 8, [], 8)
    assert extra == 1 and writes == []


def test_pool_randomized_invariants():
    rng = np.random.default_rng(0)
    pool = PagePool(8, 4)
    held = []
    for _ in range(300):
        op = rng.integers(0, 4)
        if op == 0:
            got = pool.alloc(int(rng.integers(1, 3)), "t")
            if got is not None:
                held.extend(got)
        elif op == 1 and held:
            p = held.pop(int(rng.integers(0, len(held))))
            pool.release([p], "t")
        elif op == 2 and held:
            p = held[int(rng.integers(0, len(held)))]
            q = pool.cow(p, "t")
            if q is not None and q != p:
                held[held.index(p)] = q
        elif op == 3 and held:
            p = held[int(rng.integers(0, len(held)))]
            pool.register(int(rng.integers(0, 1 << 30)), p)
        pool.check()
    pool.release(held, "t")
    pool.check()


# ---------------------------------------------------------------------------
# Paged engine parity (tiny model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    mcfg = smoke_config("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return params, mcfg


def _reqs(n=5, plen=20, max_new=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=[int(t) for t in rng.integers(2, 400, plen)],
                    max_new_tokens=max_new, **kw) for i in range(n)]


def _outs(done):
    return {r.uid: list(r.generated) for r in done}


def test_paged_bit_identical_to_unpaged_float(tiny):
    params, mcfg = tiny
    e0 = ServingEngine(params, mcfg, capacity=3, max_len=48,
                       prefill_chunks=(8,))
    ref = _outs(e0.run(_reqs()))
    e1 = ServingEngine(params, mcfg, capacity=3, max_len=48,
                       prefill_chunks=(8,), paged=True, page_size=16)
    assert _outs(e1.run(_reqs())) == ref
    assert e1.metrics.conservation()["ok"]
    assert e1.pool.stats().held == 0        # everything released after drain


def test_paged_bit_identical_unchunked(tiny):
    params, mcfg = tiny
    e0 = ServingEngine(params, mcfg, capacity=2, max_len=32, chunked=False)
    ref = _outs(e0.run(_reqs(4, plen=6, max_new=4)))
    e1 = ServingEngine(params, mcfg, capacity=2, max_len=32, chunked=False,
                       paged=True, page_size=16)
    assert _outs(e1.run(_reqs(4, plen=6, max_new=4))) == ref


def test_paged_bit_identical_kv_quant(tiny):
    import dataclasses
    params, mcfg = tiny
    mq = dataclasses.replace(mcfg, kv_quant=True)
    e0 = ServingEngine(params, mq, capacity=3, max_len=64,
                       prefill_chunks=(8,))
    ref = _outs(e0.run(_reqs(6)))
    e1 = ServingEngine(params, mq, capacity=3, max_len=64,
                       prefill_chunks=(8,), paged=True, page_size=16)
    assert _outs(e1.run(_reqs(6))) == ref


def test_paged_abfp_packed_serves_and_defaults_tile_page(tiny):
    from repro.core.abfp import QuantConfig
    params, mcfg = tiny
    q = QuantConfig(mode="abfp_packed", tile_width=16)
    eng = ServingEngine(params, mcfg, capacity=2, max_len=64,
                        prefill_chunks=(8,), quant=q, paged=True)
    assert eng.page_size == 16              # tile quantum is the page size
    done = eng.run(_reqs(4, plen=12, max_new=4))
    assert all(len(r.generated) == 4 for r in done)
    assert eng.metrics.conservation()["ok"]


def test_paged_rejects_windowed_attention(tiny):
    import dataclasses
    params, mcfg = tiny
    hybrid = dataclasses.replace(mcfg, block_pattern=("attention",),
                                 window_size=8)
    if hybrid.attention_type == "full":
        pytest.skip("smoke config cannot express windowed attention")
    with pytest.raises(ValueError, match="paged serving"):
        ServingEngine(params, hybrid, capacity=2, max_len=32, paged=True)


def test_long_request_admits_under_paging(tiny):
    """Satellite: the legacy prompt+max_new<=max_len hard reject relaxes to
    a page-budget check — a request longer than max_len still serves when
    the page table can address it (max_pages * page_size >= total)."""
    params, mcfg = tiny
    # max_len 40, PS 16 -> MP 3 -> addressable 48 tokens.
    long_req = _reqs(1, plen=30, max_new=14)[0]         # total 44 > 40
    e0 = ServingEngine(params, mcfg, capacity=1, max_len=40,
                       prefill_chunks=(8,))
    assert not e0.submit(long_req)
    assert e0.metrics.requests[0].rejected
    e1 = ServingEngine(params, mcfg, capacity=1, max_len=40,
                       prefill_chunks=(8,), paged=True, page_size=16)
    done = e1.run(_reqs(1, plen=30, max_new=14))
    assert len(done) == 1 and len(done[0].generated) == 14
    assert e1.metrics.conservation()["ok"]


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------

@pytest.mark.overload
def test_preemption_resumes_bit_identically(tiny):
    params, mcfg = tiny
    kw = dict(capacity=4, max_len=64, prefill_chunks=(8,), paged=True,
              page_size=16)
    roomy = ServingEngine(params, mcfg, **kw)
    ref = _outs(roomy.run(_reqs(8, plen=20, max_new=8)))
    tight = ServingEngine(params, mcfg, pool_pages=6, **kw)
    got = _outs(tight.run(_reqs(8, plen=20, max_new=8)))
    cons = tight.metrics.conservation()
    assert cons["preempted"] > 0            # the pool actually saturated
    assert cons["ok"] and cons["preempt_ok"]
    assert cons["preempted"] == cons["resumed"]     # no deadlines: all resume
    assert got == ref                       # recompute resume is bit-exact


@pytest.mark.overload
def test_preempted_request_can_time_out(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=2, max_len=64,
                        prefill_chunks=(8,), paged=True, page_size=16,
                        pool_pages=3)
    reqs = _reqs(4, plen=20, max_new=8, deadline=6.0)
    done = eng.run(reqs)
    cons = eng.metrics.conservation()
    assert cons["ok"] and cons["preempt_ok"]
    assert len(done) == 4
    # Any request whose final preemption was never resumed must be timed out.
    for r in eng.metrics.requests.values():
        if r.preempts > r.resumes:
            assert r.timed_out


@pytest.mark.overload
def test_priority_claims_pages_under_saturation(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=2, max_len=64,
                        prefill_chunks=(8,), paged=True, page_size=16,
                        pool_pages=4, policy="priority")
    low = _reqs(2, plen=16, max_new=24, seed=1)
    for r in low:
        r.arrival_time = 0.0
    hi = Request(uid=99, prompt=[5, 7, 11, 13], max_new_tokens=4,
                 priority=5, arrival_time=2.0)
    for r in low + [hi]:
        assert eng.submit(r)
    done = eng.drain()
    cons = eng.metrics.conservation()
    assert cons["ok"] and cons["preempt_ok"]
    assert cons["preempted"] > 0            # a low-pri victim yielded
    assert eng.metrics.requests[99].preempts == 0   # never the high-pri
    finish = {r.uid: eng.metrics.requests[r.uid].finish_time for r in done}
    assert finish[99] < max(finish[r.uid] for r in low)


# ---------------------------------------------------------------------------
# Backpressure, degraded modes, quotas
# ---------------------------------------------------------------------------

@pytest.mark.overload
def test_queue_watermark_sheds_with_retry_after(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32,
                        prefill_chunks=(8,), paged=True, page_size=16,
                        queue_watermark=2)
    reqs = _reqs(5, plen=8, max_new=4)
    accepted = [r for r in reqs if eng.submit(r)]
    shed = [r for r in reqs if r.shed]
    assert len(shed) >= 1                   # watermark 2 tripped
    for r in shed:
        assert r.done and r.retry_after is not None
        assert r.retry_after > (r.arrival_time or 0.0)
    polled = []
    while (len(eng.scheduler) or any(s is not None for s in eng.slots)
           or eng._returned):
        polled.extend(eng.poll())
    # Shed requests surface through poll(), exactly once each.
    assert sorted(r.uid for r in polled) == sorted(
        [r.uid for r in accepted] + [r.uid for r in shed])
    cons = eng.metrics.conservation()
    assert cons["ok"] and cons["shed"] == len(shed)
    assert cons["rejected"] == len(shed)    # shed counts as rejected


@pytest.mark.overload
def test_degraded_mode_caps_tokens_and_recovers_hysteretically(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=2, max_len=64,
                        prefill_chunks=(8, 16), paged=True, page_size=8,
                        pool_pages=8, page_watermarks=(0.75, 0.25),
                        degraded_max_new=2)
    done = eng.run(_reqs(6, plen=16, max_new=8))
    s = eng.metrics.summary()
    assert s["pool"]["degraded_ticks"] > 0          # pressure tripped hi
    assert s["pool"]["degraded_transitions"] >= 2   # entered AND recovered
    # Some admission happened under pressure: its generation was capped.
    assert any(0 < len(r.generated) <= 2 for r in done)
    assert eng.metrics.conservation()["ok"]
    assert eng.pool.stats().held == 0       # everything released after drain
    eng._update_degraded()                  # next observation of the pool...
    assert not eng._degraded                # ...exits via the lo watermark


@pytest.mark.overload
def test_tenant_quota_isolates_noisy_neighbor(tiny):
    params, mcfg = tiny
    eng = ServingEngine(params, mcfg, capacity=2, max_len=64,
                        prefill_chunks=(8,), paged=True, page_size=16,
                        pool_pages=8, tenant_quota=2)
    noisy = _reqs(4, plen=20, max_new=6, seed=2, tenant="noisy")
    quiet = _reqs(2, plen=8, max_new=4, seed=3, tenant="quiet")
    for i, r in enumerate(quiet):
        r.uid = 100 + i
    held_seen = {"noisy": 0, "quiet": 0}

    for r in noisy + quiet:
        assert eng.submit(r)
    while (len(eng.scheduler) or any(s is not None for s in eng.slots)
           or eng._returned):
        eng.poll()
        for t in held_seen:
            held_seen[t] = max(held_seen[t], eng.pool.tenant_held(t))
    assert eng.metrics.conservation()["ok"]
    assert held_seen["noisy"] <= 2 + 1      # quota + at most one growth page
    assert held_seen["quiet"] >= 1          # the quiet tenant actually ran
    for r in quiet:
        assert len(r.generated) == 4


# ---------------------------------------------------------------------------
# Prefix sharing
# ---------------------------------------------------------------------------

def test_prefix_sharing_saves_ticks_bit_identically(tiny):
    params, mcfg = tiny
    sysp = [int(t) for t in np.random.default_rng(7).integers(2, 400, 40)]

    def batch():
        return [Request(uid=i, prompt=sysp + [i + 2], max_new_tokens=4)
                for i in range(3)]

    kw = dict(capacity=1, max_len=64, prefill_chunks=(8,), paged=True,
              page_size=16)
    on = ServingEngine(params, mcfg, **kw)
    got = _outs(on.run(batch()))
    off = ServingEngine(params, mcfg, prefix_cache=False, **kw)
    ref = _outs(off.run(batch()))
    assert got == ref                       # shared pages change nothing
    assert on.pool.stats().prefix_hits > 0
    assert on.ticks < off.ticks             # repeated prefixes prefill once


def test_full_prompt_hit_triggers_cow_not_corruption(tiny):
    params, mcfg = tiny
    sysp = [int(t) for t in np.random.default_rng(8).integers(2, 400, 32)]

    def batch():
        return [Request(uid=i, prompt=list(sysp), max_new_tokens=4)
                for i in range(2)]

    kw = dict(capacity=1, max_len=64, prefill_chunks=(8,), paged=True,
              page_size=16)
    on = ServingEngine(params, mcfg, **kw)
    got = _outs(on.run(batch()))
    off = ServingEngine(params, mcfg, prefix_cache=False, **kw)
    assert got == _outs(off.run(batch()))
    # The second identical prompt re-fed its last token into a SHARED page:
    # that write must have split the page, not scribbled on the cache.
    assert on.pool.stats().cow_copies >= 1


def test_prefix_cache_never_serves_across_different_prefixes(tiny):
    params, mcfg = tiny
    rng = np.random.default_rng(9)
    a = [int(t) for t in rng.integers(2, 400, 20)]
    b = list(a)
    b[0] = (b[0] + 1) % 400 + 2             # same length, different 1st token

    def batch():
        return [Request(uid=0, prompt=list(a), max_new_tokens=4),
                Request(uid=1, prompt=list(b), max_new_tokens=4)]

    kw = dict(capacity=1, max_len=64, prefill_chunks=(8,), paged=True,
              page_size=16)
    on = ServingEngine(params, mcfg, **kw)
    got = _outs(on.run(batch()))
    off = ServingEngine(params, mcfg, prefix_cache=False, **kw)
    assert got == _outs(off.run(batch()))   # chain keys diverge at token 0
