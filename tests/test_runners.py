"""ModelRunner seam tests.

``runner_for`` must map every config family to the right runner class,
runner capacity accounting must match the engine's page math (attention
KV pages vs O(1) recurrent state), and — the registry smoke gate — every
registered arch must build at smoke shapes and take one jitted
decode_step through its runner's closures.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.core.abfp import QuantConfig
from repro.models import frontends, init_params
from repro.serving.pages import pages_needed
from repro.serving.runners import (
    DecoderRunner,
    EncDecRunner,
    ModelRunner,
    RecurrentRunner,
    runner_for,
)

pytestmark = pytest.mark.fleet

FLOAT = QuantConfig(mode="float")


# -- family -> runner mapping -------------------------------------------------

def test_runner_for_mapping():
    expected = {
        "smollm-360m": DecoderRunner,
        "tinyllama-1.1b": DecoderRunner,
        "gemma-7b": DecoderRunner,
        "whisper-base": EncDecRunner,
        "xlstm-350m": RecurrentRunner,
        "recurrentgemma-2b": RecurrentRunner,
    }
    for arch, cls in expected.items():
        r = runner_for(smoke_config(arch))
        assert type(r) is cls, (arch, type(r).__name__)


def test_every_registered_arch_has_a_runner():
    for arch in list_archs():
        r = runner_for(smoke_config(arch))
        assert isinstance(r, ModelRunner)


# -- capacity accounting ------------------------------------------------------

def test_decoder_capacity_cost_is_pages():
    r = runner_for(smoke_config("smollm-360m"))
    assert r.capacity_cost(33, 16) == pages_needed(33, 16) == 3
    assert r.capacity_cost(16, 16) == 1


def test_recurrent_capacity_cost_is_zero():
    r = runner_for(smoke_config("xlstm-350m"))
    assert r.fixed_state
    assert r.capacity_cost(10, 16) == 0
    assert r.capacity_cost(100_000, 16) == 0


def test_paged_ok_by_family():
    assert runner_for(smoke_config("smollm-360m")).paged_ok
    assert runner_for(smoke_config("whisper-base")).paged_ok
    assert not runner_for(smoke_config("xlstm-350m")).paged_ok
    assert not runner_for(smoke_config("recurrentgemma-2b")).paged_ok


def test_encdec_accepts_requires_features():
    mcfg = smoke_config("whisper-base")
    r = runner_for(mcfg)

    class Req:
        features = None

    req = Req()
    assert not r.accepts(req)
    req.features = np.zeros((r.enc_len, mcfg.d_model), np.float32)
    assert r.accepts(req)
    req.features = np.zeros((r.enc_len + 1, mcfg.d_model), np.float32)
    assert not r.accepts(req)


def test_decoder_accepts_anything():
    r = runner_for(smoke_config("smollm-360m"))

    class Req:
        features = None

    assert r.accepts(Req())


# -- registry smoke: every arch builds and takes one decode step --------------

@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_arch_builds_and_decodes_one_step(arch):
    mcfg = smoke_config(arch)
    runner = runner_for(mcfg)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    state = runner.init_state(2, 16)
    if runner.needs_admission:
        feats = frontends.audio_stub_features(
            jax.random.PRNGKey(1), 1, runner.enc_len, mcfg.d_model)[0]
        state = runner.make_admit(FLOAT, None)(
            params, state, feats, jnp.int32(0), jax.random.PRNGKey(2))
    step = jax.jit(runner.make_step(FLOAT, None))
    token = jnp.ones((2,), jnp.int32)
    logits, state = step(params, state, token, jax.random.PRNGKey(3))
    logits = np.asarray(logits, np.float32)
    assert logits.shape == (2, mcfg.vocab_size)
    assert np.isfinite(logits).all(), arch
