"""Substrate tests: data pipeline, optimizers, checkpointing, compression,
fault-tolerance policies, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticDataset, batch_at_step
from repro.distributed import collectives, fault
from repro.models import forward, init_params
from repro.optim import SGD, AdamW, constant, cosine_one_cycle, exponential_decay
from repro.serving import Request, ServingEngine


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=3)
    b1 = batch_at_step(cfg, 7)
    b2 = batch_at_step(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # Resumed iterator reproduces the stream.
    it = iter(SyntheticDataset(cfg))
    seq = [next(it)["tokens"] for _ in range(5)]
    it2 = iter(SyntheticDataset(cfg, start_step=3))
    np.testing.assert_array_equal(np.asarray(seq[3]),
                                  np.asarray(next(it2)["tokens"]))


def test_data_markov_structure_learnable():
    """Tokens follow the hidden transition table: successors constrained."""
    cfg = DataConfig(vocab_size=50, seq_len=64, global_batch=8, seed=0,
                     branching=2)
    from repro.data.synthetic import _transition_table
    tbl = _transition_table(cfg)
    toks = np.asarray(batch_at_step(cfg, 0)["tokens"])
    for b in range(toks.shape[0]):
        for t in range(toks.shape[1] - 1):
            assert toks[b, t + 1] in tbl[toks[b, t]]


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def test_adamw_first_step_is_lr_sized():
    opt = AdamW(schedule=constant(1e-2), weight_decay=0.0,
                grad_clip_norm=None)
    params = {"w": jnp.array([1.0, 2.0])}
    state = opt.init(params)
    grads = {"w": jnp.array([0.1, -0.2])}
    new, _ = opt.update(grads, state, params)
    # First Adam step moves ~lr in sign(grad) direction.
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [1.0 - 1e-2, 2.0 + 1e-2], rtol=1e-3)


def test_adamw_converges_quadratic():
    opt = AdamW(schedule=constant(0.1), grad_clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * state.master["w"]}
        params, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [0.0, 0.0], atol=1e-2)


def test_sgd_momentum_and_weight_decay():
    opt = SGD(schedule=constant(0.1), momentum=0.9, weight_decay=0.0)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    p1, state = opt.update({"w": jnp.array([1.0])}, state, params)
    p2, state = opt.update({"w": jnp.array([1.0])}, state, p1)
    # velocity builds: second step larger than first
    assert abs(float(p2["w"][0] - p1["w"][0])) > abs(float(p1["w"][0] - 1.0)) * 1.5


def test_schedules():
    exp = exponential_decay(1e-6, 0.3, steps_per_epoch=10)
    assert exp(0) == pytest.approx(1e-6)
    assert exp(10) == pytest.approx(0.3e-6)
    cos = cosine_one_cycle(1.0, total_steps=100, warmup_frac=0.1)
    assert float(cos(0)) == pytest.approx(0.0)
    assert float(cos(10)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.0, abs=1e-6)


def test_mixed_precision_master_weights():
    """bf16 params + f32 master: tiny updates accumulate in f32."""
    opt = SGD(schedule=constant(1e-3), momentum=0.0, weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    for _ in range(10):
        params, state = opt.update({"w": jnp.full((4,), 1e-3)}, state, params)
    # master moved by 10 * 1e-6 = 1e-5 — visible in f32, below bf16 ULP (~8e-3)
    assert float(state.master["w"][0]) < 1.0
    assert float(params["w"][0]) == 1.0
    assert state.master["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    ckpt.save(d, 5, tree, extra={"data_step": 42})
    restored, step, extra = ckpt.restore(d, tree)
    assert step == 5 and extra["data_step"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_last_k_and_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        ckpt.save(d, s, _tree(), keep_last_k=3)
    assert ckpt.all_steps(d) == [3, 4, 5]
    assert ckpt.latest_step(d) == 5


def test_checkpoint_skips_corrupt(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 1, _tree())
    ckpt.save(d, 2, _tree())
    # corrupt the newest
    os.remove(os.path.join(d, "step_0000000002", "leaf_00000.npy"))
    restored, step, _ = ckpt.restore(d, _tree())
    assert step == 1  # restart-after-failure falls back


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir never shadows a valid checkpoint."""
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_0000000009.tmp"))
    assert ckpt.latest_step(d) == 1


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------


def test_bf16_compression_roundtrip():
    g = {"w": jnp.array([1.0, 1e-3, -2.5])}
    out, _ = collectives.apply_compression(g, "bf16")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-2)


def test_int8_error_feedback_unbiased():
    """EF carries quantization residual: mean compressed grad -> true grad."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))
    ef = collectives.init_error_feedback({"w": g_true})
    acc = np.zeros(256, np.float32)
    n = 50
    for _ in range(n):
        out, ef = collectives.apply_compression({"w": g_true}, "int8", ef)
        acc += np.asarray(out["w"])
    np.testing.assert_allclose(acc / n, np.asarray(g_true), atol=2e-3)


# ---------------------------------------------------------------------------
# Fault tolerance policies
# ---------------------------------------------------------------------------


def test_restart_policy_bounds_crash_loop():
    p = fault.RestartPolicy(max_restarts=3, window_sec=100)
    assert all(p.should_restart(now=t) for t in (0, 1, 2))
    assert not p.should_restart(now=3)          # 4th within window: stop
    assert p.should_restart(now=200)            # window expired: allowed


def test_straggler_monitor_escalates():
    m = fault.StragglerMonitor(k=2.0)
    for _ in range(10):
        m.observe(1.0)
    assert not m.observe(1.5)
    for _ in range(6):
        assert m.observe(10.0)
    assert m.escalation() == "remesh"


def test_elastic_plan():
    plan = fault.plan_elastic_mesh(chips_available=240, model_parallel=16,
                                   old_shape=(16, 16))
    assert plan.new_shape == (15, 16)
    assert plan.changed and plan.lost_hosts == 16


def test_elastic_plan_raises_when_chips_below_model_parallel():
    with pytest.raises(RuntimeError):
        fault.plan_elastic_mesh(chips_available=8, model_parallel=16,
                                old_shape=(1, 16))


def test_elastic_plan_lost_hosts_clamped_at_zero():
    # More chips than the old mesh used (scale-UP replan): nothing lost.
    plan = fault.plan_elastic_mesh(chips_available=20, model_parallel=4,
                                   old_shape=(4, 4))
    assert plan.new_shape == (5, 4)
    assert plan.lost_hosts == 0


def test_plan_recovery_mesh_degrades_model_axis():
    # plan_elastic_mesh would raise at 6 chips under mp=8; the recovery
    # variant narrows the model axis instead (weights get re-programmed
    # from the clean master anyway).
    plan = fault.plan_recovery_mesh(chips_available=6, model_parallel=8,
                                    old_shape=(1, 8))
    assert plan.new_shape == (1, 6)
    with pytest.raises(RuntimeError):
        fault.plan_recovery_mesh(chips_available=0, model_parallel=4,
                                 old_shape=(1, 4))


def test_straggler_escalation_thresholds():
    m = fault.StragglerMonitor()
    assert m.escalation() == "log"           # no breaches yet
    m.flagged = 2
    assert m.escalation() == "log"           # <= 2: log only
    m.flagged = 3
    assert m.escalation() == "reslice"
    m.flagged = 5
    assert m.escalation() == "reslice"       # <= 5: reslice
    m.flagged = 6
    assert m.escalation() == "remesh"


def test_straggler_deadline_needs_five_samples():
    m = fault.StragglerMonitor(k=3.0)
    for _ in range(4):
        assert m.deadline() is None          # median model not warm yet
        assert not m.observe(1.0)            # never a breach without one
    assert m.deadline() is None              # 4 samples: still None
    m.observe(1.0)
    assert m.deadline() == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------


def test_serving_engine_batched_requests():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    eng = ServingEngine(params, mcfg, capacity=2, max_len=64)
    reqs = [Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)
    # capacity 2 with 5 requests => overlapped batching, not serial
    assert eng.ticks < sum(len(r.prompt) + r.max_new_tokens for r in reqs)


def test_serving_matches_forward_greedy():
    """Engine greedy decode == argmax of teacher-forced forward."""
    mcfg = smoke_config("smollm-360m")
    params = init_params(jax.random.PRNGKey(1), mcfg)
    prompt = [5, 9, 2]
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32)
    [done] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=1)])
    toks = jnp.asarray([prompt])
    logits, _ = forward(params, toks, mcfg)
    expect = int(jnp.argmax(logits[0, -1]))
    assert done.generated[0] == expect
