"""Tests for Differential Noise Finetuning (paper Sec. IV-B)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abfp
from repro.core.abfp import QuantConfig
from repro.core.dnf import (
    NoiseHistogram,
    capture_differential_noise,
    inject,
    select_layers_by_std,
)


def test_histogram_sampling_matches_distribution():
    """Sampling from a fitted histogram reproduces the source distribution's
    moments (the +0.5 smoothing adds a small uniform floor)."""
    rng = np.random.default_rng(0)
    src = rng.normal(0.1, 0.5, size=200_000).astype(np.float32)
    hist = NoiseHistogram.fit(src, num_bins=100)
    out = hist.sample(jax.random.PRNGKey(1), (200_000,))
    assert abs(float(out.mean()) - 0.1) < 0.02
    assert abs(float(out.std()) - 0.5) < 0.05
    # Stats captured from the raw samples.
    assert abs(float(hist.mean) - 0.1) < 0.01
    assert abs(float(hist.std) - 0.5) < 0.01


def test_histogram_smoothing_gives_full_support():
    """+0.5 smoothing: even empty bins get nonzero probability, so samples can
    land anywhere in [min, max] — including a gap in the source data."""
    src = np.concatenate([np.zeros(1000) - 1.0, np.zeros(1000) + 1.0])
    hist = NoiseHistogram.fit(src, num_bins=10)
    out = np.asarray(hist.sample(jax.random.PRNGKey(0), (50_000,)))
    in_gap = np.mean((out > -0.5) & (out < 0.5))
    assert in_gap > 0.0  # smoothing floor
    assert in_gap < 0.2  # but still rare


def test_degenerate_constant_histogram():
    hist = NoiseHistogram.fit(np.full((100,), 3.0, np.float32))
    out = hist.sample(jax.random.PRNGKey(0), (64,))
    np.testing.assert_allclose(np.asarray(out), 3.0, atol=1e-3)


def test_capture_differential_noise_abfp_vs_float():
    """dy = ABFP(x) - FLOAT(x): degenerate config => dy ~ 0; harsh config =>
    wider histogram (larger std), the paper's susceptibility signal."""
    key = jax.random.PRNGKey(0)
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.normal(kx, (32, 256), dtype=jnp.float32)
    w = jax.random.normal(kw, (256, 128), dtype=jnp.float32) * 0.06
    y_float = x @ w

    mild = QuantConfig(tile_width=8, gain=1.0, noise_lsb=0.5, out_dtype=jnp.float32)
    harsh = QuantConfig(tile_width=128, gain=1.0, noise_lsb=0.5, out_dtype=jnp.float32)
    h_mild = capture_differential_noise(y_float, abfp.abfp_matmul(x, w, mild, kn))
    h_harsh = capture_differential_noise(y_float, abfp.abfp_matmul(x, w, harsh, kn))
    assert float(h_harsh.std) > float(h_mild.std)


def test_inject_adds_noise_and_preserves_gradients():
    hist = NoiseHistogram.fit(np.random.default_rng(0).normal(0, 0.1, 10_000))

    def loss(w, x, key):
        y = x @ w
        y = inject(y, hist, key)
        return jnp.sum(y**2)

    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    g = jax.grad(loss)(w, x, jax.random.PRNGKey(3))
    assert g.shape == w.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    # Injection actually changes the output.
    y0 = x @ w
    y1 = inject(y0, hist, jax.random.PRNGKey(4))
    assert not bool(jnp.allclose(y0, y1))


def test_stacked_histograms_scan_indexing():
    hists = [
        NoiseHistogram.fit(np.random.default_rng(i).normal(0, 0.1 * (i + 1), 5000))
        for i in range(4)
    ]
    stacked = NoiseHistogram.stack(hists)
    assert stacked.edges.shape == (4, 101)

    def body(carry, l):
        h = stacked.layer(l)
        s = h.sample(jax.random.fold_in(jax.random.PRNGKey(0), l), (2000,))
        return carry, s.std()

    _, stds = jax.lax.scan(body, 0, jnp.arange(4))
    # Std increases with layer index by construction.
    assert bool(jnp.all(jnp.diff(stds) > 0))


def test_select_layers_by_std():
    hists = [
        NoiseHistogram.fit(np.random.default_rng(i).normal(0, s, 1000))
        for i, s in enumerate([0.01, 0.5, 0.02, 0.8])
    ]
    mask = select_layers_by_std(hists, top_fraction=0.5)
    assert mask == [False, True, False, True]
