"""Chunked-prefill equivalence tests.

In ``mode="float"`` the chunked prefill path must be BIT-identical to
feeding the same tokens through ``decode_step`` one at a time: KV caches
(bf16/f32 and int8 ABFP-quantized), ring-buffer window caches (including
wraparound), recurrent states (rglru conv+h, mlstm, slstm), and the
next-token logits.  ABFP modes get statistical equivalence only — the
Pallas noise PRNG salts by grid position, so a chunked matmul grid draws
different noise than S decode-shaped grids.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.models import (
    Numerics,
    decode_step,
    init_decode_state,
    init_params,
    prefill,
)
from repro.serving import Request, ServingEngine

B = 2


def _mcfg(name):
    if name == "tinyllama-kvquant":
        return dataclasses.replace(smoke_config("tinyllama-1.1b"),
                                   kv_quant=True)
    if name == "hybrid-window8":
        # Window smaller than the prompt: exercises ring-buffer wraparound
        # inside and across chunks.
        return dataclasses.replace(smoke_config("recurrentgemma-2b"),
                                   window_size=8)
    return smoke_config(name)


def _decode_loop(params, mcfg, toks, max_len):
    state = init_decode_state(mcfg, toks.shape[0], max_len=max_len)
    logits = None
    for t in range(toks.shape[1]):
        logits, state = decode_step(params, state, toks[:, t], mcfg)
    return logits, state


def _chunked(params, mcfg, toks, chunks, max_len, pad=2):
    """Prefill ``toks`` in the given chunk split, each chunk padded by
    ``pad`` bogus positions to exercise the n_tokens masking."""
    state = init_decode_state(mcfg, toks.shape[0], max_len=max_len)
    logits, pos = None, 0
    for c in chunks:
        tk = jnp.zeros((toks.shape[0], c + pad), jnp.int32)
        tk = tk.at[:, :c].set(toks[:, pos:pos + c])
        logits, state = prefill(params, state, tk,
                                jnp.full((toks.shape[0],), c, jnp.int32),
                                mcfg)
        pos += c
    assert pos == toks.shape[1]
    return logits, state


def _assert_trees_bitwise(t1, t2):
    flat1, def1 = jax.tree.flatten(t1)
    flat2, def2 = jax.tree.flatten(t2)
    assert def1 == def2
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


ARCHS = ["tinyllama-1.1b", "recurrentgemma-2b", "xlstm-350m",
         "tinyllama-kvquant", "hybrid-window8"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_bit_identical(arch):
    """Chunked prefill == token-by-token decode, bit for bit (float mode):
    same KV caches / recurrent states / positions AND same last-token
    logits, through uneven chunk splits with padded buckets."""
    mcfg = _mcfg(arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    L = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              mcfg.vocab_size)
    logits_ref, state_ref = _decode_loop(params, mcfg, toks, max_len=24)
    logits, state = _chunked(params, mcfg, toks, chunks=(5, 7), max_len=24)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_ref))
    _assert_trees_bitwise(state, state_ref)


def test_prefill_window_wraparound_bit_identical():
    """Prompt much longer than the sliding window: the ring buffer wraps
    several times within and across chunks."""
    mcfg = _mcfg("hybrid-window8")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    L = 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              mcfg.vocab_size)
    logits_ref, state_ref = _decode_loop(params, mcfg, toks, max_len=40)
    logits, state = _chunked(params, mcfg, toks, chunks=(9, 11), max_len=40)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_ref))
    _assert_trees_bitwise(state, state_ref)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-2b",
                                  "xlstm-350m"])
def test_prefill_idle_slot_untouched(arch):
    """A slot with n_tokens == 0 keeps its ENTIRE state slice bit-identical
    (prefilling and decoding slots share the batch), and the active slot is
    unaffected by its neighbor's n."""
    mcfg = _mcfg(arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                              mcfg.vocab_size)
    state0 = init_decode_state(mcfg, B, max_len=16)

    _, state_both = prefill(params, state0, toks,
                            jnp.array([6, 6], jnp.int32), mcfg)
    _, state_one = prefill(params, state0, toks,
                           jnp.array([6, 0], jnp.int32), mcfg)

    def slot(tree, i):
        def pick(path, leaf):
            names = [str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path]
            b_axis = 1 if "groups" in names else 0
            return leaf if leaf.ndim <= b_axis else jnp.take(leaf, i, b_axis)
        return jax.tree_util.tree_map_with_path(pick, tree)

    # slot 0 advanced identically; slot 1 bitwise untouched
    _assert_trees_bitwise(slot(state_one, 0), slot(state_both, 0))
    _assert_trees_bitwise(slot(state_one, 1), slot(state0, 1))


def test_prefill_abfp_statistical():
    """ABFP chunked prefill draws different kernel-noise than token-by-token
    (grid-shape salted PRNG) but must stay statistically equivalent."""
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    L = 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              mcfg.vocab_size)
    quant = QuantConfig(mode="abfp_ref", tile_width=32, gain=2.0,
                        noise_lsb=0.5)

    state = init_decode_state(mcfg, B, max_len=16)
    for t in range(L):
        nx = Numerics(quant, jax.random.PRNGKey(100 + t))
        logits_ref, state = decode_step(params, state, toks[:, t], mcfg, nx)

    state = init_decode_state(mcfg, B, max_len=16)
    nx = Numerics(quant, jax.random.PRNGKey(999))
    logits, state = prefill(params, state, toks,
                            jnp.full((B,), L, jnp.int32), mcfg, nx)

    a = np.asarray(logits, np.float32).ravel()
    b = np.asarray(logits_ref, np.float32).ravel()
    assert np.all(np.isfinite(a))
    c = np.corrcoef(a, b)[0, 1]
    assert c > 0.8, c


def _greedy_workload(mcfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, mcfg.vocab_size,
                                        17 + 9 * i).tolist(),
                    max_new_tokens=4)
            for i in range(n)]


def test_engine_chunked_matches_legacy():
    """End-to-end: the chunked engine generates exactly the same tokens as
    legacy prefill-in-decode, with far fewer ticks.  Covers slot reuse
    (more requests than capacity -> jitted reset), prefilling/decoding
    coexistence (uneven prompt lengths), and chunk bucketing."""
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)

    e1 = ServingEngine(params, mcfg, capacity=2, max_len=64, chunked=False)
    d1 = e1.run(_greedy_workload(mcfg, 3))
    e2 = ServingEngine(params, mcfg, capacity=2, max_len=64, chunked=True,
                       prefill_chunks=(4, 16))
    d2 = e2.run(_greedy_workload(mcfg, 3))

    assert {r.uid: r.generated for r in d1} == {r.uid: r.generated for r in d2}
    assert e2.ticks < e1.ticks


def test_engine_rejects_oversized_request():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    eng = ServingEngine(params, mcfg, capacity=1, max_len=16)
    with pytest.raises(ValueError):
        eng.try_admit(Request(uid=0, prompt=list(range(1, 16)),
                              max_new_tokens=8))
    # max_new == 0 must still reserve one cache slot (chunk-scatter padding).
    with pytest.raises(ValueError):
        eng.try_admit(Request(uid=1, prompt=list(range(1, 17)),
                              max_new_tokens=0))
    # An empty prompt has no token to condition the first generation on —
    # rejecting it beats silently decoding from a stale _next_input.
    with pytest.raises(ValueError):
        eng.try_admit(Request(uid=4, prompt=[], max_new_tokens=2))
    # run() rejects oversized requests up front instead of crashing the
    # serve loop mid-flight; the rest of the workload is served.
    ok = Request(uid=2, prompt=[1, 2, 3], max_new_tokens=2)
    bad = Request(uid=3, prompt=list(range(1, 16)), max_new_tokens=8)
    done = eng.run([ok, bad])
    assert {r.uid for r in done} == {2, 3}
    assert next(r for r in done if r.uid == 3).generated == []
    assert len(next(r for r in done if r.uid == 2).generated) == 2


# ---------------------------------------------------------------------------
# Cache-full boundary: padding lanes must never race the last real write
# ---------------------------------------------------------------------------


def test_chunk_append_at_cache_boundary_keeps_real_write():
    """Regression (found failing, then fixed): with length + n_tokens ==
    S_max, the chunk scatter's padding lanes used to CLAMP onto index
    S_max - 1 — the very slot the last real token writes — and the
    duplicate-index race let the stale value win, silently corrupting the
    final K/V append.  Padding lanes past the cache end are dropped now;
    the boundary append must match a padding-free 1-token chunk exactly."""
    from repro.models.layers import chunk_append_attend

    b, s, h, d, s_max = 2, 4, 2, 8, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    cache = {
        "k": jax.random.normal(jax.random.fold_in(key, 3), (b, s_max, h, d)),
        "v": jax.random.normal(jax.random.fold_in(key, 4), (b, s_max, h, d)),
        "length": jnp.array([s_max - 1, s_max - 3], jnp.int32),
    }
    n_tokens = jnp.array([1, 2], jnp.int32)     # slot 0 fills the cache

    out, new = chunk_append_attend(q, k, v, dict(cache),
                                   n_tokens=n_tokens, window=0)
    # Padding-free reference: per-slot 1-token appends (slot 0) / the same
    # chunk without excess lanes (slot 1 via a 2-token chunk).
    out1, ref = chunk_append_attend(q[:, :2], k[:, :2], v[:, :2],
                                    dict(cache), n_tokens=n_tokens, window=0)
    np.testing.assert_array_equal(np.asarray(new["k"][0, s_max - 1]),
                                  np.asarray(k[0, 0]))
    np.testing.assert_array_equal(np.asarray(new["k"]), np.asarray(ref["k"]))
    np.testing.assert_array_equal(np.asarray(new["v"]), np.asarray(ref["v"]))
    np.testing.assert_array_equal(np.asarray(out[:, :2]), np.asarray(out1))
    np.testing.assert_array_equal(np.asarray(new["length"]),
                                  np.asarray(cache["length"]) + [1, 2])
