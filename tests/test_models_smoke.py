"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU; asserts shapes and no NaNs.  (Deliverable f.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, SHAPES, get_config, smoke_config
from repro.core.abfp import QuantConfig
from repro.models import (
    Numerics,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    param_count,
)
from repro.models.frontends import audio_stub_features, vision_stub_embeddings

B, S = 2, 16


def _inputs(mcfg, key):
    """(tokens_or_embeds, encoder_features) for a smoke batch."""
    kt, kf = jax.random.split(key)
    if mcfg.frontend == "vision_stub":
        x = vision_stub_embeddings(kt, B, S, mcfg.d_model, jnp.float32)
    else:
        x = jax.random.randint(kt, (B, S), 0, mcfg.vocab_size)
    enc = None
    if mcfg.is_encoder_decoder:
        enc = audio_stub_features(kf, B, S, mcfg.d_model, jnp.float32)
    return x, enc


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    mcfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    x, enc = _inputs(mcfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(
        lambda p, x, e: forward(p, x, mcfg, encoder_features=e)
    )(params, x, enc)
    assert logits.shape == (B, S, mcfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_grad_smoke(arch):
    """One train step's worth of grads: finite, right structure."""
    mcfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    x, enc = _inputs(mcfg, jax.random.PRNGKey(1))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, mcfg.vocab_size)

    def loss_fn(p):
        logits, aux = forward(p, x, mcfg, encoder_features=enc)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # Gradients actually flow to the first-layer weights.
    gnorm = sum(float(jnp.abs(g).sum()) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_smoke(arch):
    mcfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    state = init_decode_state(mcfg, B, max_len=32)

    enc_kv = None
    if mcfg.is_encoder_decoder:
        from repro.models import encode
        from repro.models.lm import _cross_kv
        nx = Numerics(QuantConfig(mode="float"))
        enc = audio_stub_features(jax.random.PRNGKey(3), B, S, mcfg.d_model,
                                  jnp.float32)
        enc_out = encode(params, enc, mcfg, nx)
        enc_kv = _cross_kv(params, enc_out, mcfg, nx)

    token = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, s, t, mcfg, enc_kv=enc_kv))
    for _ in range(3):
        logits, state = step(params, state, token)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert logits.shape == (B, mcfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state["position"][0]) == 3


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-moe-1b-a400m",
                                  "recurrentgemma-2b", "xlstm-350m"])
def test_abfp_forward_smoke(arch):
    """The zoo runs end-to-end in ABFP simulation mode (QAT forward)."""
    mcfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    x, enc = _inputs(mcfg, jax.random.PRNGKey(1))
    nx = Numerics(
        QuantConfig(mode="abfp_ref", tile_width=32, gain=2.0, noise_lsb=0.5),
        key=jax.random.PRNGKey(9))
    logits, _ = jax.jit(
        lambda p, x, e: forward(p, x, mcfg, Numerics(nx.quant, jax.random.PRNGKey(9)),
                                encoder_features=e)
    )(params, x, enc)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # ABFP output differs from float but is correlated with it.
    logits_f, _ = jax.jit(
        lambda p, x, e: forward(p, x, mcfg, encoder_features=e)
    )(params, x, enc)
    c = np.corrcoef(np.asarray(logits).ravel(), np.asarray(logits_f).ravel())[0, 1]
    assert not np.allclose(np.asarray(logits), np.asarray(logits_f))
    assert c > 0.5, c


def test_decode_matches_forward_tinyllama():
    """Teacher-forced forward and step-by-step decode agree (KV-cache
    correctness)."""
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, mcfg.vocab_size)
    logits_fwd, _ = forward(params, toks, mcfg)

    state = init_decode_state(mcfg, B, max_len=16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, state, toks[:, t], mcfg)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_fwd), rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_hybrid():
    """Same consistency check through RG-LRU + sliding-window layers."""
    mcfg = smoke_config("recurrentgemma-2b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, mcfg.vocab_size)
    logits_fwd, _ = forward(params, toks, mcfg)

    state = init_decode_state(mcfg, B, max_len=16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, state, toks[:, t], mcfg)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_fwd), rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    mcfg = smoke_config("xlstm-350m")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, mcfg.vocab_size)
    logits_fwd, _ = forward(params, toks, mcfg)

    state = init_decode_state(mcfg, B, max_len=16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, state, toks[:, t], mcfg)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_fwd), rtol=3e-2, atol=3e-2)


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned hyperparameters."""
    expect = {
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch, (l, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (l, d, h, kv, ff, v), arch
    assert get_config("kimi-k2-1t-a32b").num_experts == 384
    assert get_config("kimi-k2-1t-a32b").experts_per_token == 8
    assert get_config("granite-moe-1b-a400m").num_experts == 32
    assert get_config("gemma-7b").head_dim == 256
    assert len(SHAPES) == 4
