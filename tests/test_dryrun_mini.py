"""Mini dry-run integration test: the real dryrun.py entry point on a small
placeholder mesh (subprocess so XLA device count doesn't leak into other
tests)."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run(args, timeout=560):
    # Artifacts go to a throwaway dir so these mini runs never pollute
    # experiments/dryrun (test_dryrun_artifacts_exist_and_parse validates
    # the real grid set there).
    with tempfile.TemporaryDirectory() as art:
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", *args],
            capture_output=True, text=True, timeout=timeout,
            env={**ENV, "REPRO_DRYRUN_ART_DIR": art}, cwd="/root/repo")


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("whisper-base", "prefill_32k"),         # enc-dec
    ("whisper-base", "train_4k"),            # enc-dec train
    ("xlstm-350m", "long_500k"),             # ssm long-context decode
])
def test_dryrun_cell_mini_mesh(arch, shape):
    r = _run(["--arch", arch, "--shape", shape, "--mesh-shape", "4,2"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "compiled OK" in r.stdout


@pytest.mark.slow
def test_dryrun_multipod_mini():
    """3-axis (pod, data, model) mesh lowers and compiles."""
    r = _run(["--arch", "smollm-360m", "--shape", "decode_32k",
              "--mesh-shape", "2,2,2"])
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_abfp_mode_mini():
    r = _run(["--arch", "smollm-360m", "--shape", "prefill_32k",
              "--mesh-shape", "4,2", "--quant", "abfp"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_dryrun_artifacts_exist_and_parse():
    """The full-mesh grid artifacts (written by the deliverable-e run) are
    valid JSON with the fields the roofline analysis needs."""
    art = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")
    if not os.path.isdir(art):
        pytest.skip("experiments/dryrun artifacts not generated in this "
                    "checkout (run launch.dryrun --grid to produce them)")
    files = [f for f in os.listdir(art) if f.endswith(".json")]
    if len(files) < 64:  # expected 32 cells x 2 meshes
        pytest.skip(f"partial artifact set ({len(files)} files) — full grid "
                    "not generated (run launch.dryrun --grid)")
    meshes = set()
    for f in files:
        with open(os.path.join(art, f)) as fh:
            d = json.load(fh)
        for k in ("arch", "shape", "mesh", "flops_per_device",
                  "collectives", "live_bytes_per_device"):
            assert k in d, (f, k)
        meshes.add(d["mesh"])
    assert {"16x16", "2x16x16"} <= meshes
