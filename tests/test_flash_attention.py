"""Flash-attention Pallas kernel vs the pure-jnp online-softmax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import chunked_attention

TOL = dict(rtol=2e-3, atol=2e-3)


def _qkv(b, sq, skv, h, kh, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, kh, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, kh, d), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (2, 256, 256, 4, 4, 64),       # MHA
    (2, 256, 256, 8, 2, 64),       # GQA 4:1
    (1, 384, 640, 5, 1, 128),      # MQA, odd sizes, Sq != Skv
])
def test_flash_matches_oracle_causal(shape):
    b, sq, skv, h, kh, d = shape
    q, k, v = _qkv(*shape)
    y_k = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    y_r = chunked_attention(q, k, v, causal=True, chunk=128)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


def test_flash_non_causal():
    q, k, v = _qkv(2, 192, 320, 4, 4, 64, seed=1)
    y_k = flash_attention(q, k, v, causal=False, bq=128, bk=128)
    y_r = chunked_attention(q, k, v, causal=False, chunk=64)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


def test_flash_sliding_window():
    q, k, v = _qkv(1, 512, 512, 2, 2, 64, seed=2)
    y_k = flash_attention(q, k, v, causal=True, window=128, bq=128, bk=128)
    y_r = chunked_attention(q, k, v, causal=True, window=128, chunk=128)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **TOL)


def test_flash_bf16_inputs():
    q, k, v = _qkv(1, 256, 256, 4, 4, 64, seed=3, dtype=jnp.bfloat16)
    y_k = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    y_r = chunked_attention(q, k, v, causal=True, chunk=128)
    assert y_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_block_shape_invariance():
    q, k, v = _qkv(1, 512, 512, 2, 2, 64, seed=4)
    y1 = flash_attention(q, k, v, bq=128, bk=128)
    y2 = flash_attention(q, k, v, bq=256, bk=512)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), **TOL)


def test_flash_in_model_forward_matches():
    """Full-model prefill with use_flash_attention matches the XLA path."""
    import dataclasses

    from repro.configs import smoke_config
    from repro.models import forward, init_params

    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              mcfg.vocab_size)
    y_ref, _ = forward(params, toks, mcfg)
    mcfg_f = dataclasses.replace(mcfg, use_flash_attention=True)
    y_fl, _ = forward(params, toks, mcfg_f)
    np.testing.assert_allclose(np.asarray(y_fl), np.asarray(y_ref),
                               rtol=5e-3, atol=5e-3)
