"""Unit tests for the ABFP core numerics (Eqs. 1-8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import abfp
from repro.core.abfp import QuantConfig
from repro.kernels.ref import abfp_matmul_ref


# ---------------------------------------------------------------------------
# Eq. 1 — quantizer
# ---------------------------------------------------------------------------


def test_quantizer_lattice_and_clamp():
    delta = abfp.quant_delta(8)  # 1/127
    v = jnp.array([0.0, delta, 2.5 * delta, -3.4 * delta, 5.0, -5.0])
    q = abfp.quantize(v, delta, 1.0)
    # On-lattice values unchanged; off-lattice rounded; out-of-range clamped.
    np.testing.assert_allclose(q[0], 0.0)
    np.testing.assert_allclose(q[1], delta, rtol=1e-6)
    np.testing.assert_allclose(q[3], -3.0 * delta, rtol=1e-6)
    np.testing.assert_allclose(q[4], 1.0)
    np.testing.assert_allclose(q[5], -1.0)
    # round-half-even: 2.5 -> 2, 3.5 -> 4
    np.testing.assert_allclose(q[2], 2.0 * delta, rtol=1e-6)
    q35 = abfp.quantize(jnp.array(3.5 * delta), delta, 1.0)
    np.testing.assert_allclose(q35, 4.0 * delta, rtol=1e-6)


def test_quantizer_idempotent():
    delta = abfp.quant_delta(6)
    v = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q1 = abfp.quantize(v, delta, 1.0)
    q2 = abfp.quantize(q1, delta, 1.0)
    np.testing.assert_allclose(q1, q2, atol=0)


def test_quant_delta_values():
    assert abfp.quant_delta(8) == pytest.approx(1 / 127)
    assert abfp.quant_delta(6) == pytest.approx(1 / 31)


# ---------------------------------------------------------------------------
# Tile scales
# ---------------------------------------------------------------------------


def test_tile_scales_max_abs_and_zero_tile():
    v = jnp.array([[1.0, -3.0, 0.5, 2.0], [0.0, 0.0, 0.0, 0.0]])
    s = abfp.tile_scales(v)
    np.testing.assert_allclose(s, [3.0, 0.0])
    np.testing.assert_allclose(abfp.safe_scale(s), [3.0, 1.0])


def test_weight_tiles_shapes_and_padding():
    cfg = QuantConfig(tile_width=8)
    w = jax.random.normal(jax.random.PRNGKey(1), (20, 16))
    w_q, s_w = abfp.quantize_weight_tiles(w, cfg)
    assert w_q.shape == (3, 8, 16)  # ceil(20/8)=3 tiles
    assert s_w.shape == (3, 16)
    # Integer codes in [-L, L], L = 2^(b-1)-1 = 127.
    assert bool(jnp.all(jnp.abs(w_q) <= 127))
    np.testing.assert_allclose(np.asarray(w_q), np.asarray(jnp.round(w_q)))
    # The value lattice w_q * delta_w * s_w approximates w.
    recon = (w_q * abfp.quant_delta(8)).reshape(24, 16)[:20] * 1.0
    # per-tile scale broadcast
    s_full = jnp.repeat(s_w, 8, axis=0)[:20]
    np.testing.assert_allclose(
        np.asarray(recon * s_full), np.asarray(w), atol=0.05)


# ---------------------------------------------------------------------------
# ABFP matmul: scan path vs independent einsum oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 32, 128])
@pytest.mark.parametrize("gain", [1.0, 8.0])
@pytest.mark.parametrize("noise", [0.0, 0.5])
def test_scan_matches_oracle(n, gain, noise):
    # f32 output: the two paths differ only in f32 accumulation order, so a
    # tight tolerance holds (bf16 output would round that tiny difference
    # across an ULP boundary).
    cfg = QuantConfig(tile_width=n, gain=gain, noise_lsb=noise,
                      out_dtype=jnp.float32)
    key = jax.random.PRNGKey(42)
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.normal(kx, (6, 200)).astype(jnp.bfloat16)
    w = (jax.random.laplace(kw, (200, 48)) * 0.1).astype(jnp.bfloat16)
    y_scan = abfp.abfp_matmul(x, w, cfg, kn)
    y_ref = abfp_matmul_ref(x, w, cfg, kn)
    np.testing.assert_allclose(
        np.asarray(y_scan), np.asarray(y_ref), rtol=1e-5, atol=1e-5
    )


def test_abfp_close_to_float_at_high_bits():
    """With many bits, no noise, gain 1, ABFP ~= exact matmul."""
    # f32 scales: with bf16 scale storage (the paper's default) the error
    # floor is the bf16 rounding of the per-tile max (~0.4%), which dominates
    # at high bitwidths.
    cfg = QuantConfig(tile_width=32, bits_w=16, bits_x=16, bits_y=24, gain=1.0,
                      noise_lsb=0.0, out_dtype=jnp.float32,
                      scale_dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 128), dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32), dtype=jnp.float32)
    y = abfp.abfp_matmul(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-3, atol=1e-3)


def test_gain_saturation_tradeoff():
    """Paper Sec. III-B: at large tiles moderate gain reduces error, huge gain
    saturates.  Check error(G=8) < error(G=1) and error(G=256) > error(G=8)
    for tile 128 at 8/8/8."""
    key = jax.random.PRNGKey(7)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (16, 768), dtype=jnp.float32)
    w = jax.random.laplace(kw, (768, 256)) * (1 / np.sqrt(768))
    y_exact = x @ w

    def err(gain):
        cfg = QuantConfig(tile_width=128, gain=gain, noise_lsb=0.0,
                          out_dtype=jnp.float32)
        y = abfp.abfp_matmul(x, w, cfg)
        return float(jnp.sqrt(jnp.mean((y - y_exact) ** 2)))

    e1, e8, e256 = err(1.0), err(8.0), err(256.0)
    assert e8 < e1, (e1, e8)
    assert e256 > e8, (e8, e256)


def test_small_tile_prefers_low_gain():
    """At tile 8 the output range is small; gain mostly saturates (Table II row 1)."""
    key = jax.random.PRNGKey(3)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (16, 768), dtype=jnp.float32)
    w = jax.random.laplace(kw, (768, 256)) * (1 / np.sqrt(768))
    y_exact = x @ w

    def err(gain):
        cfg = QuantConfig(tile_width=8, gain=gain, noise_lsb=0.0,
                          out_dtype=jnp.float32)
        y = abfp.abfp_matmul(x, w, cfg)
        return float(jnp.sqrt(jnp.mean((y - y_exact) ** 2)))

    assert err(1.0) < err(16.0)


def test_noise_statistics():
    """E ~ U(-n*dY/2, +n*dY/2): mean ~ 0, var ~ (n*dY)^2/12."""
    cfg = QuantConfig(tile_width=128, bits_y=8, noise_lsb=0.5)
    e = abfp.ams_noise(jax.random.PRNGKey(0), (200_000,), cfg)
    lsb = 128 * abfp.quant_delta(8)
    assert abs(float(e.mean())) < lsb * 0.01
    np.testing.assert_allclose(float(e.var()), lsb**2 / 12, rtol=0.05)
    assert float(jnp.abs(e).max()) <= lsb / 2


def test_digital_vs_ams_quantization_order():
    """Paper's aside under Eq. 4: digital (accumulate-then-quantize) has lower
    error than AMS (quantize-then-accumulate) at the same bitwidths."""
    key = jax.random.PRNGKey(11)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (8, 512), dtype=jnp.float32)
    w = jax.random.laplace(kw, (512, 64)) * (1 / np.sqrt(512))
    y_exact = x @ w
    cfg = QuantConfig(tile_width=128, gain=1.0, noise_lsb=0.0, out_dtype=jnp.float32)
    y_ams = abfp.abfp_matmul(x, w, cfg)
    y_dig = abfp.digital_bfp_matmul(x, w, cfg)
    err_ams = float(jnp.mean((y_ams - y_exact) ** 2))
    err_dig = float(jnp.mean((y_dig - y_exact) ** 2))
    assert err_dig < err_ams, (err_dig, err_ams)


# ---------------------------------------------------------------------------
# STE (QAT backward, Eq. 8)
# ---------------------------------------------------------------------------


def test_ste_grads_match_plain_matmul():
    cfg = QuantConfig(tile_width=32, noise_lsb=0.0)
    key = jax.random.PRNGKey(5)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (4, 64), dtype=jnp.float32)
    w = jax.random.normal(kw, (64, 16), dtype=jnp.float32)

    def loss_abfp(x, w):
        return jnp.sum(abfp.abfp_matmul_ste(x, w, cfg, None).astype(jnp.float32) ** 0 *
                       abfp.abfp_matmul_ste(x, w, cfg, None).astype(jnp.float32))

    def loss_plain(x, w):
        return jnp.sum(x @ w)

    gx_a, gw_a = jax.grad(lambda x, w: jnp.sum(
        abfp.abfp_matmul_ste(x, w, cfg, None).astype(jnp.float32)), argnums=(0, 1))(x, w)
    gx_p, gw_p = jax.grad(loss_plain, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_a), np.asarray(gx_p), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_a), np.asarray(gw_p), rtol=1e-5)


def test_quantize_ste_identity_gradient():
    delta = abfp.quant_delta(8)
    g = jax.grad(lambda v: jnp.sum(abfp.quantize_ste(v, delta, 1.0)))(
        jnp.linspace(-0.9, 0.9, 32))
    np.testing.assert_allclose(np.asarray(g), np.ones(32))


def test_batched_leading_dims():
    cfg = QuantConfig(tile_width=8, noise_lsb=0.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 40))
    w = jax.random.normal(jax.random.PRNGKey(1), (40, 24))
    y = abfp.abfp_matmul(x, w, cfg)
    assert y.shape == (2, 3, 24)
    assert y.dtype == jnp.bfloat16
    assert not bool(jnp.any(jnp.isnan(y.astype(jnp.float32))))


def test_per_tile_scaling_outlier_robustness():
    """Paper Sec. III-A: per-vector adaptive scales give 'reduced sensitivity
    to outliers' vs coarser scale granularity.  With rare 50x outliers, small
    tiles confine the resolution loss to the outlier's own tile, while a
    whole-row scale (tile = K, the per-tensor limit) destroys resolution for
    everything.

    Also documents a measured NEGATIVE result for the Sec. VI future-work
    percentile knob: under per-TILE scaling, percentile clipping makes errors
    WORSE (the clipped outlier corrupts whole dot products), because ABFP
    already localizes outliers — exactly the paper's argument for adaptive
    per-vector scales.  (`scale_percentile` remains available for per-tensor
    style deployments.)"""
    key = jax.random.PRNGKey(21)
    kx, kw, ko = jax.random.split(key, 3)
    x = jax.random.normal(kx, (16, 512), dtype=jnp.float32)
    mask = jax.random.bernoulli(ko, 0.01, x.shape)
    x = jnp.where(mask, x * 50.0, x)
    w = jax.random.laplace(kw, (512, 64)) * (1 / np.sqrt(512))
    y_exact = x @ w

    def err(cfg):
        y = abfp.abfp_matmul(x, w, cfg)
        return float(jnp.median(jnp.abs(y - y_exact)))

    small = QuantConfig(tile_width=32, bits_x=6, bits_w=6, noise_lsb=0.0,
                        out_dtype=jnp.float32)
    row = small.replace(tile_width=512)        # per-tensor-like granularity
    assert err(small) < err(row), (err(small), err(row))
    # Negative result: percentile clipping on top of per-tile scales hurts.
    pct = small.replace(scale_percentile=97.0)
    assert err(pct) > err(small), (err(pct), err(small))


def test_percentile_100_equals_max():
    cfg_max = QuantConfig(tile_width=32, noise_lsb=0.0, out_dtype=jnp.float32)
    cfg_100 = cfg_max.replace(scale_percentile=100.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 16)) * 0.1
    np.testing.assert_array_equal(
        np.asarray(abfp.abfp_matmul(x, w, cfg_max)),
        np.asarray(abfp.abfp_matmul(x, w, cfg_100)))


def test_packed_output_error_bound_envelopes_response():
    """The scale-statistic bound is a true envelope: no unit-scale input
    drives any output column above it, so a probe reading ABOVE the bound
    is unambiguous corruption (serving.faults uses the converse, a zero
    fingerprint, for dead columns)."""
    cfg = QuantConfig(tile_width=32, gain=4.0, noise_lsb=0.5,
                      out_dtype=jnp.float32)
    w = jax.random.laplace(jax.random.PRNGKey(3), (200, 48)) * 0.08
    pw = abfp.pack_abfp_weight(w, cfg)
    bound = abfp.packed_output_error_bound(pw, cfg)
    assert bound.shape == (pw.n_padded,)
    x = jnp.clip(jax.random.normal(jax.random.PRNGKey(4), (16, 200)), -1, 1)
    y = abfp_matmul_ref(x, w, cfg, key=jax.random.PRNGKey(5))
    assert bool(jnp.all(jnp.abs(y) <= bound[: w.shape[1]] + 1e-6))
    # The bound tracks the programmed scales linearly: doubling every tile
    # scale (a gross drift) exactly doubles the envelope.
    drifted = jax.tree.map(lambda a: a, pw)
    object.__setattr__(drifted, "scales", pw.scales * 2)
    np.testing.assert_allclose(np.asarray(
        abfp.packed_output_error_bound(drifted, cfg)),
        2.0 * np.asarray(bound), rtol=1e-6)
