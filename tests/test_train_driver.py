"""End-to-end launcher tests: train driver with checkpoint/resume (the
fault-tolerance restart path), QAT mode, and the serve driver."""

import subprocess
import sys

import pytest

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}


def _run(mod, *args, timeout=560):
    return subprocess.run(
        [sys.executable, "-m", mod, *args],
        capture_output=True, text=True, timeout=timeout, env=ENV,
        cwd="/root/repo")


@pytest.mark.slow
def test_train_checkpoint_resume(tmp_path):
    ckpt = str(tmp_path / "run")
    r1 = _run("repro.launch.train", "--arch", "smollm-360m", "--reduced",
              "--steps", "8", "--ckpt-dir", ckpt, "--ckpt-every", "4",
              "--batch", "4", "--seq", "32")
    assert r1.returncode == 0, r1.stdout + r1.stderr
    assert "checkpoint ->" in r1.stdout

    # Simulated restart-after-failure: same command resumes, not restarts.
    r2 = _run("repro.launch.train", "--arch", "smollm-360m", "--reduced",
              "--steps", "12", "--ckpt-dir", ckpt, "--ckpt-every", "4",
              "--batch", "4", "--seq", "32")
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 8" in r2.stdout


@pytest.mark.slow
def test_train_qat_mode(tmp_path):
    r = _run("repro.launch.train", "--arch", "smollm-360m", "--reduced",
             "--steps", "3", "--quant", "qat", "--batch", "2", "--seq", "32")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "quant=qat" in r.stdout and "done" in r.stdout


@pytest.mark.slow
def test_train_compression_and_microbatches(tmp_path):
    r = _run("repro.launch.train", "--arch", "smollm-360m", "--reduced",
             "--steps", "4", "--batch", "4", "--seq", "32",
             "--microbatches", "2", "--compression", "int8")
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.slow
def test_serve_driver():
    r = _run("repro.launch.serve", "--arch", "smollm-360m", "--reduced",
             "--requests", "3", "--capacity", "2", "--max-new", "3")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "3 requests" in r.stdout
