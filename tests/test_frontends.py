"""Frontend stub tests (models/frontends.py) + EncDec admission.

The stubs stand in for real audio/vision towers: deterministic per key,
fixed shape/dtype, finite.  The EncDec admission test closes the loop —
stub features submitted with a request must flow through the runner's
admission encoder pass and produce a completed request whose decode saw
the cached cross-attention KV (different audio => different tokens).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import frontends, init_params
from repro.serving import Request, ServingEngine
from repro.serving.runners import runner_for

pytestmark = pytest.mark.fleet


# -- stub shape / dtype / determinism ----------------------------------------

def test_audio_stub_shape_and_dtype():
    out = frontends.audio_stub_features(jax.random.PRNGKey(0), 2, 16, 64)
    assert out.shape == (2, 16, 64)
    assert out.dtype == jnp.bfloat16
    out32 = frontends.audio_stub_features(jax.random.PRNGKey(0), 1, 8, 32,
                                          dtype=jnp.float32)
    assert out32.dtype == jnp.float32
    assert np.isfinite(np.asarray(out32, np.float32)).all()


def test_vision_stub_shape_and_dtype():
    out = frontends.vision_stub_embeddings(jax.random.PRNGKey(0), 2, 16, 64)
    assert out.shape == (2, 16, 64)
    assert out.dtype == jnp.bfloat16


def test_stubs_deterministic_per_key():
    a = frontends.audio_stub_features(jax.random.PRNGKey(7), 1, 8, 32)
    b = frontends.audio_stub_features(jax.random.PRNGKey(7), 1, 8, 32)
    c = frontends.audio_stub_features(jax.random.PRNGKey(8), 1, 8, 32)
    assert np.array_equal(np.asarray(a, np.float32),
                          np.asarray(b, np.float32))
    assert not np.array_equal(np.asarray(a, np.float32),
                              np.asarray(c, np.float32))


# -- EncDec admission consumes the stubs --------------------------------------

@pytest.fixture(scope="module")
def whisper():
    mcfg = smoke_config("whisper-base")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return params, mcfg


def _feats(mcfg, runner, seed):
    return np.asarray(frontends.audio_stub_features(
        jax.random.PRNGKey(seed), 1, runner.enc_len, mcfg.d_model)[0],
        np.float32)


def test_whisper_request_completes_via_submit_poll_drain(whisper):
    params, mcfg = whisper
    runner = runner_for(mcfg)
    eng = ServingEngine(params, mcfg, capacity=2, max_len=32)
    reqs = [Request(uid=i, prompt=[1, 2, 3 + i], max_new_tokens=4,
                    features=_feats(mcfg, runner, 5))
            for i in range(3)]
    for r in reqs:
        assert eng.submit(r)
    done = eng.drain()
    assert len(done) == 3
    assert all(len(r.generated) == 4 for r in done)
    assert eng.metrics.conservation()["ok"]


def test_whisper_rejects_missing_or_misshapen_features(whisper):
    params, mcfg = whisper
    runner = runner_for(mcfg)
    eng = ServingEngine(params, mcfg, capacity=1, max_len=32)
    no_feats = Request(uid=0, prompt=[1, 2], max_new_tokens=2)
    assert not eng.submit(no_feats)
    assert no_feats.done
    bad = Request(uid=1, prompt=[1, 2], max_new_tokens=2,
                  features=np.zeros((runner.enc_len + 3, mcfg.d_model),
                                    np.float32))
    assert not eng.submit(bad)
    assert bad.done


def test_whisper_decode_conditions_on_audio(whisper):
    """Same prompt, different audio => the cached cross-attention KV must
    change the decode logits (argmax may coincide on untrained weights,
    so compare the logit vectors, and greedy tokens for determinism)."""
    import jax.numpy as jnp

    from repro.core.abfp import QuantConfig

    params, mcfg = whisper
    runner = runner_for(mcfg)
    quant = QuantConfig(mode="float")
    step = jax.jit(runner.make_step(quant, None))
    admit = jax.jit(runner.make_admit(quant, None))

    def logits_for(feat_seed):
        state = runner.init_state(1, 8)
        state = admit(params, state, jnp.asarray(_feats(mcfg, runner,
                                                        feat_seed)),
                      jnp.int32(0), jax.random.PRNGKey(0))
        logits, _ = step(params, state, jnp.asarray([5], jnp.int32),
                         jax.random.PRNGKey(1))
        return np.asarray(logits, np.float32)

    base, same, other = (logits_for(11), logits_for(11), logits_for(12))
    assert np.array_equal(base, same)            # deterministic per audio
    assert not np.array_equal(base, other)       # audio reaches decode
