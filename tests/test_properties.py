"""Property-based tests (hypothesis) for ABFP invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import abfp
from repro.core.abfp import QuantConfig
from repro.core.dnf import NoiseHistogram

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def quant_cfgs(draw):
    return QuantConfig(
        tile_width=draw(st.sampled_from([8, 32, 128])),
        bits_w=draw(st.sampled_from([4, 6, 8])),
        bits_x=draw(st.sampled_from([4, 6, 8])),
        bits_y=draw(st.sampled_from([6, 8, 10])),
        gain=float(draw(st.sampled_from([1, 2, 4, 8, 16]))),
        noise_lsb=0.0,
        out_dtype=jnp.float32,
    )


@given(bits=st.integers(2, 12),
       data=st.lists(st.floats(-4, 4, allow_nan=False), min_size=1,
                     max_size=64))
@settings(**SETTINGS)
def test_quantizer_bounds_and_lattice(bits, data):
    """Q output is clamped to [-tau, tau] and lies on the delta lattice."""
    v = jnp.asarray(data, jnp.float32)
    delta = abfp.quant_delta(bits)
    q = abfp.quantize(v, delta, 1.0)
    assert bool(jnp.all(jnp.abs(q) <= 1.0 + 1e-6))
    ratio = np.asarray(q / delta, np.float64)
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-3)


@given(cfg=quant_cfgs(), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_abfp_error_bounded_by_tilewise_budget(cfg, seed):
    """|ABFP(xw) - xw| is bounded by the per-tile error budget:
    operand quantization + ADC bin, summed over tiles with bf16-scale slack."""
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    m, k, n = 4, 2 * cfg.tile_width, 8
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.2
    y = abfp.abfp_matmul(x, w, cfg)
    y_ref = x @ w
    t = k // cfg.tile_width
    sx = float(jnp.abs(x).max())
    sw = float(jnp.abs(w).max())
    nn = cfg.tile_width
    # worst case per tile: operand rounding + ADC bin + gain saturation
    # (the ADC clamps G*p at +-n, i.e. p at +-n/G: up to (1-1/G)*n*s of a
    # tile's range is clipped away — the paper's Fig. 2 MSB loss).
    per_tile = (nn * (cfg.delta_x + cfg.delta_w + cfg.delta_x * cfg.delta_w)
                * sx * sw * 1.02                       # bf16 scale slack
                + (nn * cfg.delta_y) * sx * sw / cfg.gain
                + nn * sx * sw * (1.0 - 1.0 / cfg.gain))
    bound = t * per_tile + 1e-4
    err = float(jnp.abs(y - y_ref).max())
    assert err <= bound * 1.5 + 1e-3, (err, bound, cfg)


@given(cfg=quant_cfgs(), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(0.25, 4.0))
@settings(**SETTINGS)
def test_abfp_scale_equivariance_power_of_two(cfg, seed, scale):
    """ABFP(a*x @ w) ~ a * ABFP(x @ w) for power-of-two a (exact bf16
    scales are closed under power-of-two multiplication)."""
    a = 2.0 ** round(np.log2(scale))
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (3, cfg.tile_width * 2), jnp.float32)
    w = jax.random.normal(kw, (cfg.tile_width * 2, 5), jnp.float32) * 0.3
    y1 = abfp.abfp_matmul(x * a, w, cfg)
    y2 = abfp.abfp_matmul(x, w, cfg) * a
    # Saturation interacts with scaling only through the ADC clamp, which is
    # scale-free in normalized units — results match to quantizer tolerance.
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=0.15, atol=0.15 * a)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_abfp_determinism(seed):
    cfg = QuantConfig(tile_width=32, noise_lsb=0.5, out_dtype=jnp.float32)
    key = jax.random.PRNGKey(seed)
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.normal(kx, (4, 96))
    w = jax.random.normal(kw, (96, 16))
    y1 = abfp.abfp_matmul(x, w, cfg, kn)
    y2 = abfp.abfp_matmul(x, w, cfg, kn)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


@given(data=st.lists(st.floats(-10, 10, allow_nan=False, allow_infinity=False),
                     min_size=2, max_size=500))
@settings(**SETTINGS)
def test_histogram_sample_within_support(data):
    hist = NoiseHistogram.fit(np.asarray(data, np.float32))
    out = np.asarray(hist.sample(jax.random.PRNGKey(0), (256,)))
    lo, hi = float(hist.edges[0]), float(hist.edges[-1])
    assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)


@given(seed=st.integers(0, 1000), n=st.sampled_from([8, 32, 128]))
@settings(**SETTINGS)
def test_gain_divides_out_without_saturation(seed, n):
    """If G*p never clips the ADC, gain changes only ADC resolution:
    error(G) <= error(1) + one output bin.

    NOTE: ABFP normalizes each tile to unit range, so "small inputs" do NOT
    avoid saturation (the scales cancel) — we must *check* for clipping on
    the actual integer partial products.  When clipping does occur, gain
    trades saturation for resolution: exactly the paper's Fig. 2 tradeoff,
    covered by test_abfp_core.test_gain_saturation_tradeoff.
    """
    from hypothesis import assume

    cfg1 = QuantConfig(tile_width=n, gain=1.0, bits_y=14, noise_lsb=0.0,
                       out_dtype=jnp.float32)
    cfgG = cfg1.replace(gain=4.0)
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (2, n)) * 0.05
    w = jax.random.normal(kw, (n, 3)) * 0.05

    # Clipping check on the exact integer partials under the HIGHER gain.
    x_q, _ = abfp.quantize_input_tiles(x, cfgG)
    w_q, _ = abfp.quantize_weight_tiles(w, cfgG)
    p = jnp.einsum("mtn,tno->tmo", x_q, w_q)
    lvl = abfp.quant_levels(cfgG.bits_y)
    assume(bool(jnp.all(jnp.abs(p * cfgG.adc_code_scale) < lvl)))

    y1 = abfp.abfp_matmul(x, w, cfg1)
    yg = abfp.abfp_matmul(x, w, cfgG)
    ref = x @ w
    e1 = float(jnp.abs(y1 - ref).max())
    eg = float(jnp.abs(yg - ref).max())
    bin_scale = n * abfp.quant_delta(14) * float(
        jnp.abs(x).max() * jnp.abs(w).max())
    assert eg <= e1 + bin_scale + 1e-5
