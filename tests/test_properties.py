"""Property-based tests: scheduler/serving invariants on randomized arrival
traces (seeded RNG — always run), plus hypothesis suites for ABFP numerics
and trace-shrinking variants of the scheduler properties when hypothesis is
installed (the CPU CI image ships without it; the seeded tests keep the
invariants enforced there).

Scheduler invariants under test (satellite of the sharded-serving PR):

  * request conservation — every submitted request is either completed or
    rejected after ``drain()``; nothing is lost, duplicated, or left in a
    slot/queue;
  * no starvation under the priority policy — within a priority class,
    tenants round-robin on fewest-admissions-so-far, so a flooding tenant
    cannot push another tenant's requests arbitrarily far back;
  * TTFT is never earlier than arrival (nor is admission), on the
    simulated clock, and the clock itself is monotone across polls.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - CI image has no hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import abfp
from repro.core.abfp import QuantConfig
from repro.core.dnf import NoiseHistogram
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import POLICIES, get_scheduler

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Randomized arrival traces (simulated clock)
# ---------------------------------------------------------------------------


def _trace(rng, n, *, tenants=3, mean_gap=1.0, max_prompt=12):
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(mean_gap))
        plen = int(rng.integers(1, max_prompt))
        reqs.append(Request(
            uid=i, prompt=[1 + (i + j) % 97 for j in range(plen)],
            max_new_tokens=int(rng.integers(1, 5)),
            arrival_time=round(t, 3),
            priority=int(rng.integers(0, 3)),
            tenant=f"t{int(rng.integers(tenants))}"))
    return reqs


def _pop_all(sched, reqs, *, step=0.7):
    """Drive pop() on an advancing simulated clock until the queue drains.
    Returns the pop order."""
    for r in reqs:
        sched.add(r)
    now, order = 0.0, []
    while len(sched):
        r = sched.pop(now)
        if r is None:
            now += step
            continue
        order.append(r)
    return order


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("seed", range(8))
def test_scheduler_pop_conserves_and_respects_arrivals(policy, seed):
    """Every policy: pops exactly the submitted set (no loss, no dupes) and
    never releases a request before its arrival time."""
    rng = np.random.default_rng(seed)
    reqs = _trace(rng, int(rng.integers(1, 40)))
    sched = get_scheduler(policy)
    now_seen = {}
    for r in reqs:
        sched.add(r)
    now, order = 0.0, []
    while len(sched):
        r = sched.pop(now)
        if r is None:
            assert sched.next_arrival() is not None
            now = max(now + 0.5, sched.next_arrival())
            continue
        order.append(r)
        now_seen[r.uid] = now
    assert sorted(r.uid for r in order) == sorted(r.uid for r in reqs)
    for r in order:
        assert r.arrival_time <= now_seen[r.uid]


@pytest.mark.parametrize("flood", [2, 5, 10])
def test_priority_tenant_round_robin_no_starvation(flood):
    """Within one priority class, a tenant flooding the queue ``flood``x
    harder cannot starve the other: admissions alternate (fewest-admits
    tenant first), so at every prefix the admitted counts differ by at most
    one while both tenants still have pending requests."""
    n_b = 6
    reqs = ([Request(uid=i, prompt=[1], arrival_time=0.0, tenant="flood")
             for i in range(flood * n_b)]
            + [Request(uid=1000 + i, prompt=[1], arrival_time=0.0,
                       tenant="quiet") for i in range(n_b)])
    order = _pop_all(get_scheduler("priority"), reqs)
    admitted = {"flood": 0, "quiet": 0}
    for r in order[: 2 * n_b]:           # both tenants pending in this span
        admitted[r.tenant] += 1
        assert abs(admitted["flood"] - admitted["quiet"]) <= 1, admitted
    # The quiet tenant's last request leaves within the alternating span,
    # not after the flood drains.
    last_quiet = max(i for i, r in enumerate(order) if r.tenant == "quiet")
    assert last_quiet <= 2 * n_b - 1


def test_priority_classes_strictly_ordered():
    """Between classes priority stays strict: a higher class empties first
    even when submitted last (fairness is within-class only)."""
    reqs = ([Request(uid=i, prompt=[1], arrival_time=0.0, priority=0,
                     tenant=f"t{i % 2}") for i in range(4)]
            + [Request(uid=10 + i, prompt=[1], arrival_time=0.0, priority=5,
                       tenant="t0") for i in range(3)])
    order = _pop_all(get_scheduler("priority"), reqs)
    assert [r.priority for r in order] == [5, 5, 5, 0, 0, 0, 0]


# ---------------------------------------------------------------------------
# Engine-level invariants on randomized traces (simulated clock)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    from repro.configs import smoke_config
    from repro.models import init_params

    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)
    return mcfg, params


@pytest.mark.parametrize("seed,policy", [(0, "fcfs"), (1, "sjf"),
                                         (2, "priority"), (3, "priority")])
def test_engine_conservation_and_ttft_bounds(engine_setup, seed, policy):
    """Open-loop serve of a random trace: submitted == completed + rejected,
    no request lingers in a slot or queue, TTFT/admission never precede
    arrival, and the simulated clock is monotone."""
    mcfg, params = engine_setup
    rng = np.random.default_rng(seed)
    max_len = 24
    reqs = _trace(rng, 12, max_prompt=10)
    # Force a couple of rejections into the trace (prompt > max_len).
    for r in reqs[:: 5]:
        r.prompt = [2] * (max_len + 1)
    eng = ServingEngine(params, mcfg, capacity=2, max_len=max_len,
                        quant=QuantConfig(mode="float"), seed=seed,
                        prefill_chunks=(4, 8), policy=policy)
    accepted, rejected = [], []
    for r in reqs:
        (accepted if eng.submit(r) else rejected).append(r)

    finished, clocks = [], [eng.now]
    while len(eng.scheduler) or any(s is not None for s in eng.slots):
        finished.extend(eng.poll())
        clocks.append(eng.now)

    # Conservation: completed + rejected == submitted, queue and batch empty.
    assert len(finished) + len(rejected) == len(reqs)
    assert sorted(r.uid for r in finished + rejected) \
        == sorted(r.uid for r in reqs)
    assert all(r.done for r in reqs)
    assert len(eng.scheduler) == 0 and all(s is None for s in eng.slots)
    assert all(len(r.generated) == r.max_new_tokens for r in accepted)
    assert all(not r.generated for r in rejected)

    # Clock monotone; per-request causality on the simulated clock.
    assert all(b >= a for a, b in zip(clocks, clocks[1:]))
    for r in accepted:
        m = eng.metrics.requests[r.uid]
        assert m.arrival_time == r.arrival_time
        assert m.admit_time >= r.arrival_time
        assert m.first_token_time >= r.arrival_time     # TTFT >= 0
        assert m.ttft >= 0 and m.e2e >= m.ttft
        assert m.finish_time >= m.first_token_time


# ---------------------------------------------------------------------------
# Hypothesis suites (skipped wholesale when hypothesis is not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=25, deadline=None)

    @st.composite
    def traces(draw):
        n = draw(st.integers(1, 30))
        gaps = draw(st.lists(st.floats(0.0, 3.0), min_size=n, max_size=n))
        arrivals = np.cumsum(gaps)
        return [Request(uid=i, prompt=[1] * draw(st.integers(1, 8)),
                        arrival_time=float(arrivals[i]),
                        priority=draw(st.integers(0, 2)),
                        tenant=f"t{draw(st.integers(0, 2))}")
                for i in range(n)]

    @given(trace=traces(),
           policy=st.sampled_from(sorted(POLICIES)))
    @settings(**SETTINGS)
    def test_scheduler_conservation_hypothesis(trace, policy):
        sched = get_scheduler(policy)
        for r in trace:
            sched.add(r)
        now, seen = 0.0, []
        while len(sched):
            r = sched.pop(now)
            if r is None:
                now = max(now + 1.0, sched.next_arrival())
                continue
            assert r.arrival_time <= now
            seen.append(r.uid)
        assert sorted(seen) == sorted(r.uid for r in trace)

    @st.composite
    def quant_cfgs(draw):
        return QuantConfig(
            tile_width=draw(st.sampled_from([8, 32, 128])),
            bits_w=draw(st.sampled_from([4, 6, 8])),
            bits_x=draw(st.sampled_from([4, 6, 8])),
            bits_y=draw(st.sampled_from([6, 8, 10])),
            gain=float(draw(st.sampled_from([1, 2, 4, 8, 16]))),
            noise_lsb=0.0,
            out_dtype=jnp.float32,
        )

    @given(bits=st.integers(2, 12),
           data=st.lists(st.floats(-4, 4, allow_nan=False), min_size=1,
                         max_size=64))
    @settings(**SETTINGS)
    def test_quantizer_bounds_and_lattice(bits, data):
        """Q output is clamped to [-tau, tau] and lies on the delta
        lattice."""
        v = jnp.asarray(data, jnp.float32)
        delta = abfp.quant_delta(bits)
        q = abfp.quantize(v, delta, 1.0)
        assert bool(jnp.all(jnp.abs(q) <= 1.0 + 1e-6))
        ratio = np.asarray(q / delta, np.float64)
        np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-3)

    @given(cfg=quant_cfgs(), seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_abfp_error_bounded_by_tilewise_budget(cfg, seed):
        """|ABFP(xw) - xw| is bounded by the per-tile error budget:
        operand quantization + ADC bin, summed over tiles with bf16-scale
        slack."""
        key = jax.random.PRNGKey(seed)
        kx, kw = jax.random.split(key)
        m, k, n = 4, 2 * cfg.tile_width, 8
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32) * 0.2
        y = abfp.abfp_matmul(x, w, cfg)
        y_ref = x @ w
        t = k // cfg.tile_width
        sx = float(jnp.abs(x).max())
        sw = float(jnp.abs(w).max())
        nn = cfg.tile_width
        # worst case per tile: operand rounding + ADC bin + gain saturation
        # (the ADC clamps G*p at +-n, i.e. p at +-n/G: up to (1-1/G)*n*s of
        # a tile's range is clipped away — the paper's Fig. 2 MSB loss).
        per_tile = (nn * (cfg.delta_x + cfg.delta_w
                          + cfg.delta_x * cfg.delta_w)
                    * sx * sw * 1.02                   # bf16 scale slack
                    + (nn * cfg.delta_y) * sx * sw / cfg.gain
                    + nn * sx * sw * (1.0 - 1.0 / cfg.gain))
        bound = t * per_tile + 1e-4
        err = float(jnp.abs(y - y_ref).max())
        assert err <= bound * 1.5 + 1e-3, (err, bound, cfg)

    @given(cfg=quant_cfgs(), seed=st.integers(0, 2**31 - 1),
           scale=st.floats(0.25, 4.0))
    @settings(**SETTINGS)
    def test_abfp_scale_equivariance_power_of_two(cfg, seed, scale):
        """ABFP(a*x @ w) ~ a * ABFP(x @ w) for power-of-two a (exact bf16
        scales are closed under power-of-two multiplication)."""
        a = 2.0 ** round(np.log2(scale))
        key = jax.random.PRNGKey(seed)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (3, cfg.tile_width * 2), jnp.float32)
        w = jax.random.normal(kw, (cfg.tile_width * 2, 5),
                              jnp.float32) * 0.3
        y1 = abfp.abfp_matmul(x * a, w, cfg)
        y2 = abfp.abfp_matmul(x, w, cfg) * a
        # Saturation interacts with scaling only through the ADC clamp,
        # which is scale-free in normalized units — results match to
        # quantizer tolerance.
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=0.15, atol=0.15 * a)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_abfp_determinism(seed):
        cfg = QuantConfig(tile_width=32, noise_lsb=0.5, out_dtype=jnp.float32)
        key = jax.random.PRNGKey(seed)
        kx, kw, kn = jax.random.split(key, 3)
        x = jax.random.normal(kx, (4, 96))
        w = jax.random.normal(kw, (96, 16))
        y1 = abfp.abfp_matmul(x, w, cfg, kn)
        y2 = abfp.abfp_matmul(x, w, cfg, kn)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    @given(data=st.lists(st.floats(-10, 10, allow_nan=False,
                                   allow_infinity=False),
                         min_size=2, max_size=500))
    @settings(**SETTINGS)
    def test_histogram_sample_within_support(data):
        hist = NoiseHistogram.fit(np.asarray(data, np.float32))
        out = np.asarray(hist.sample(jax.random.PRNGKey(0), (256,)))
        lo, hi = float(hist.edges[0]), float(hist.edges[-1])
        assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)

    @given(seed=st.integers(0, 1000), n=st.sampled_from([8, 32, 128]))
    @settings(**SETTINGS)
    def test_gain_divides_out_without_saturation(seed, n):
        """If G*p never clips the ADC, gain changes only ADC resolution:
        error(G) <= error(1) + one output bin.

        NOTE: ABFP normalizes each tile to unit range, so "small inputs" do
        NOT avoid saturation (the scales cancel) — we must *check* for
        clipping on the actual integer partial products.  When clipping
        does occur, gain trades saturation for resolution: exactly the
        paper's Fig. 2 tradeoff, covered by
        test_abfp_core.test_gain_saturation_tradeoff.
        """
        cfg1 = QuantConfig(tile_width=n, gain=1.0, bits_y=14, noise_lsb=0.0,
                           out_dtype=jnp.float32)
        cfgG = cfg1.replace(gain=4.0)
        key = jax.random.PRNGKey(seed)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (2, n)) * 0.05
        w = jax.random.normal(kw, (n, 3)) * 0.05

        # Clipping check on the exact integer partials at the HIGHER gain.
        x_q, _ = abfp.quantize_input_tiles(x, cfgG)
        w_q, _ = abfp.quantize_weight_tiles(w, cfgG)
        p = jnp.einsum("mtn,tno->tmo", x_q, w_q)
        lvl = abfp.quant_levels(cfgG.bits_y)
        assume(bool(jnp.all(jnp.abs(p * cfgG.adc_code_scale) < lvl)))

        y1 = abfp.abfp_matmul(x, w, cfg1)
        yg = abfp.abfp_matmul(x, w, cfgG)
        ref = x @ w
        e1 = float(jnp.abs(y1 - ref).max())
        eg = float(jnp.abs(yg - ref).max())
        bin_scale = n * abfp.quant_delta(14) * float(
            jnp.abs(x).max() * jnp.abs(w).max())
        assert eg <= e1 + bin_scale + 1e-5


# ---------------------------------------------------------------------------
# Overload traces: paged pool + preemption + quotas (seeded — always run)
# ---------------------------------------------------------------------------


def _overload_trace(seed, n=14, *, deadlines=True):
    """Bursty 3-tenant trace (mean gap 0.4 ticks) that saturates a 3-page
    pool: mixed priorities, deadlines on every third request."""
    rng = np.random.default_rng(100 + seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.4))
        plen = int(rng.integers(2, 10))
        reqs.append(Request(
            uid=i, prompt=[1 + (i + j) % 97 for j in range(plen)],
            max_new_tokens=int(rng.integers(2, 6)),
            arrival_time=round(t, 3),
            priority=int(rng.integers(0, 3)),
            tenant=f"t{int(rng.integers(3))}",
            deadline=(round(t, 3) + 20.0)
            if (deadlines and i % 3 == 0) else None))
    return reqs


def _check_overload_run(params, mcfg, reqs, ref_reqs, *, pool_pages,
                        tenant_quota, expect_preemption):
    kw = dict(capacity=3, max_len=32, prefill_chunks=(4, 8), paged=True,
              page_size=8, policy="priority")
    tight = ServingEngine(params, mcfg, pool_pages=pool_pages,
                          tenant_quota=tenant_quota, **kw)
    done = tight.run(reqs)
    cons = tight.metrics.conservation()

    # Conservation extended with preemption: every preempted request was
    # resumed or timed out, nothing lost, nothing double-counted.
    assert cons["ok"] and cons["preempt_ok"]
    assert cons["resumed"] <= cons["preempted"]
    if expect_preemption:
        assert cons["preempted"] > 0
    assert len(done) == len(reqs) and all(r.done for r in reqs)
    assert tight.pool.stats().held == 0

    # No starvation under quota: a request without a deadline can be
    # preempted and throttled but never dropped — it always completes.
    for r in done:
        if r.deadline is None:
            assert len(r.generated) == r.max_new_tokens

    # Preempted requests resume BIT-IDENTICALLY: greedy decode of every
    # non-timed-out request matches a roomy no-deadline reference run.
    roomy = ServingEngine(params, mcfg, **kw)
    ref = {r.uid: list(r.generated) for r in roomy.run(ref_reqs)}
    for r in done:
        if not r.timed_out:
            assert list(r.generated) == ref[r.uid], r.uid


@pytest.mark.overload
@pytest.mark.parametrize("seed", range(4))
def test_overload_trace_preemption_properties(engine_setup, seed):
    """Saturating trace against a 3-page pool (each request needs up to 2
    pages, 3 slots): preemption MUST fire, conservation + preempt_ok hold,
    no-deadline requests always complete, resumes are bit-exact."""
    mcfg, params = engine_setup
    _check_overload_run(params, mcfg, _overload_trace(seed),
                        _overload_trace(seed, deadlines=False),
                        pool_pages=3, tenant_quota=2,
                        expect_preemption=True)


if HAVE_HYPOTHESIS:

    @st.composite
    def overload_traces(draw):
        n = draw(st.integers(4, 12))
        gaps = draw(st.lists(st.floats(0.0, 1.0), min_size=n, max_size=n))
        arrivals = np.cumsum(gaps)
        return [Request(
            uid=i,
            prompt=[1 + (i + j) % 97
                    for j in range(draw(st.integers(1, 9)))],
            max_new_tokens=draw(st.integers(1, 5)),
            arrival_time=float(round(arrivals[i], 3)),
            priority=draw(st.integers(0, 2)),
            tenant=f"t{draw(st.integers(0, 2))}",
            deadline=(float(round(arrivals[i], 3)) + 20.0)
            if draw(st.booleans()) else None)
            for i in range(n)]

    @given(trace=overload_traces())
    @settings(max_examples=8, deadline=None)
    @pytest.mark.overload
    def test_overload_trace_preemption_hypothesis(engine_setup, trace):
        mcfg, params = engine_setup
        import copy
        ref_reqs = copy.deepcopy(trace)
        for r in ref_reqs:
            r.deadline = None
        # Preemption fires only when the trace actually saturates the
        # pool, so it is not asserted here — the invariants must hold
        # either way (hypothesis shrinks to quiet traces too).
        _check_overload_run(params, mcfg, trace, ref_reqs,
                            pool_pages=3, tenant_quota=2,
                            expect_preemption=False)
