"""Multi-model fleet serving: N single-model lanes on one shared clock.

``ServingEngine(models={name: (params, mcfg[, runner])})`` constructs a
:class:`FleetEngine` (via ``ServingEngine.__new__`` dispatch) instead of a
single-model engine.  Each entry becomes a **lane** — a full single-model
``ServingEngine`` with its own slot partition, scheduler, metrics, and
(when pageable) its own page-pool quota — and the fleet multiplexes the
lanes round-robin on a shared simulated clock, the multi-model analog of
one analog accelerator board hosting several programmed arrays.

Partitioning rules
------------------
* ``capacity`` is the TOTAL slot count, split near-equally across lanes;
  ``model_split={name: slots}`` overrides individual lanes (every lane
  gets at least one slot).
* ``paged=True`` applies only to lanes whose runner reports
  ``paged_ok`` (full-attention decoders).  Recurrent lanes hold O(1)
  fixed-size state — they bypass page accounting entirely and are never
  preempted under pool pressure (structurally: no pool exists for them).
* ``pool_pages`` is split across pageable lanes proportionally to their
  slot share, so one model's long-context burst cannot evict another
  model's cache pages.

Clock protocol
--------------
``self.now`` is the fleet clock.  Before any lane operation the lane's
clock is synced forward to the fleet clock; after the operation the fleet
clock absorbs the lane's advance.  When every lane is idle the fleet
jumps straight to the earliest next arrival across all lanes (never past
a busier lane's work, because lanes with arrived work are always served
first).

Routing
-------
``Request.model`` names the lane.  With a single lane, unrouted requests
(``model=None``) default to it; with several, routing is mandatory and an
unknown or missing model name raises ``KeyError`` listing the fleet.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.serving.engine import Request, ServingEngine
from repro.serving.runners import runner_for
from repro.serving.stream import OverlappedStream


def _split_capacity(total: int, names: List[str],
                    overrides: Optional[Dict[str, int]]) -> Dict[str, int]:
    """Near-equal slot split with per-model overrides; every lane >= 1."""
    overrides = dict(overrides or {})
    unknown = set(overrides) - set(names)
    if unknown:
        raise KeyError(f"model_split names unknown models {sorted(unknown)}; "
                       f"fleet serves {sorted(names)}")
    out = {n: int(overrides[n]) for n in names if n in overrides}
    rest = [n for n in names if n not in out]
    budget = total - sum(out.values())
    if rest:
        if budget < len(rest):
            raise ValueError(
                f"capacity {total} leaves {budget} slots for "
                f"{len(rest)} un-split lanes (each needs >= 1)")
        base, extra = divmod(budget, len(rest))
        for i, n in enumerate(rest):
            out[n] = base + (1 if i < extra else 0)
    bad = {n: c for n, c in out.items() if c < 1}
    if bad:
        raise ValueError(f"every lane needs >= 1 slot, got {bad}")
    return out


class FleetEngine(ServingEngine):
    """Multiplexed multi-model serving engine (see module docstring).

    Intentionally does NOT call ``ServingEngine.__init__``: the fleet owns
    no model state of its own — it owns lanes, the shared clock, and the
    routing table.
    """

    def __init__(self, params=None, mcfg=None, *, models,
                 capacity: int = 8,
                 model_split: Optional[Dict[str, int]] = None,
                 paged: bool = False,
                 pool_pages: Optional[int] = None,
                 **lane_kwargs):
        if params is not None or mcfg is not None:
            raise TypeError(
                "fleet mode takes models={name: (params, mcfg[, runner])}; "
                "do not also pass positional params/mcfg")
        if not models:
            raise ValueError("models must name at least one lane")
        names = list(models)
        split = _split_capacity(int(capacity), names, model_split)

        resolved = {}
        for name, entry in models.items():
            p, cfg = entry[0], entry[1]
            runner = entry[2] if len(entry) > 2 else runner_for(cfg)
            resolved[name] = (p, cfg, runner)

        pageable = [n for n in names if paged and resolved[n][2].paged_ok]
        pool_split: Dict[str, Optional[int]] = {n: None for n in names}
        if pool_pages is not None and pageable:
            ptotal = sum(split[n] for n in pageable)
            acc = 0
            for i, n in enumerate(pageable):
                if i == len(pageable) - 1:
                    pool_split[n] = int(pool_pages) - acc   # remainder
                else:
                    share = int(pool_pages) * split[n] // ptotal
                    pool_split[n] = max(1, share)
                    acc += pool_split[n]

        # Overlapped fleets share ONE delivery pipeline: a single stream
        # (one worker, one dispatch-ahead bound) serves every lane, so the
        # board-level in-flight depth is bounded fleet-wide rather than
        # per-lane.
        self._shared_stream = None
        if lane_kwargs.get("overlap"):
            depth = lane_kwargs.pop("inflight", 4)
            self._shared_stream = OverlappedStream(depth=depth)
            lane_kwargs.setdefault("stream", self._shared_stream)

        self.lanes: Dict[str, ServingEngine] = {}
        for name in names:
            p, cfg, runner = resolved[name]
            self.lanes[name] = ServingEngine(
                p, cfg, runner=runner, capacity=split[name],
                paged=paged and runner.paged_ok,
                pool_pages=pool_split[name],
                **lane_kwargs)
        self.capacity = int(capacity)
        self._clock = lane_kwargs.get("clock")
        self.now = self._clock() if self._clock is not None else 0.0
        self._rr = 0                    # round-robin cursor over lanes

    # -- clock sync -------------------------------------------------------
    def _enter(self, lane: ServingEngine) -> None:
        lane.now = max(lane.now, self.now)

    def _leave(self, lane: ServingEngine) -> None:
        self.now = max(self.now, lane.now)

    def _lane_for(self, req: Request) -> ServingEngine:
        if req.model is None:
            if len(self.lanes) == 1:
                return next(iter(self.lanes.values()))
            raise KeyError(
                f"request {req.uid} has no model routing key; fleet serves "
                f"{sorted(self.lanes)}")
        try:
            return self.lanes[req.model]
        except KeyError:
            raise KeyError(
                f"request {req.uid} routed to unknown model "
                f"{req.model!r}; fleet serves {sorted(self.lanes)}") from None

    @staticmethod
    def _has_work(lane: ServingEngine) -> bool:
        """Work servable NOW: occupied slots, arrived queue entries,
        finalized-outside-step requests awaiting a poll, or overlapped
        deliveries not yet handed back."""
        return (any(s is not None for s in lane.slots)
                or lane.scheduler.pending(lane.now) > 0
                or bool(lane._returned)
                or bool(lane._delivered))

    # -- open-loop API ----------------------------------------------------
    def submit(self, req: Request) -> bool:
        lane = self._lane_for(req)
        self._enter(lane)
        ok = lane.submit(req)
        self._leave(lane)
        return ok

    def poll(self) -> List[Request]:
        """One fleet round: serve one lane's poll, round-robin over lanes
        that have work at the shared clock.  When every lane is idle, jump
        the clock to the earliest next arrival across the fleet (the next
        poll then serves that lane)."""
        names = list(self.lanes)
        for lane in self.lanes.values():
            self._enter(lane)
        busy = [n for n in names if self._has_work(self.lanes[n])]
        if not busy:
            if any(l._stream.pending() for l in self.lanes.values()):
                # Everything dispatched, nothing feedable: wait for the
                # shared pipeline to deliver, then hand the tokens back.
                out: List[Request] = []
                for lane in self.lanes.values():
                    lane.sync()
                    out.extend(lane._drain_delivered())
                return out
            nxts = [self.lanes[n].scheduler.next_arrival() for n in names]
            nxts = [t for t in nxts if t is not None]
            if nxts:
                self.now = max(self.now, min(nxts))
            return []
        # Round-robin among busy lanes, resuming after the last-served one.
        order = busy
        for off in range(len(names)):
            cand = names[(self._rr + off) % len(names)]
            if cand in busy:
                order = [cand]
                self._rr = (names.index(cand) + 1) % len(names)
                break
        lane = self.lanes[order[0]]
        self._enter(lane)
        out = lane.poll()
        self._leave(lane)
        return out

    def drain(self) -> List[Request]:
        finished: List[Request] = []
        while any(len(l.scheduler)
                  or any(s is not None for s in l.slots)
                  or l._returned
                  or l._stream.pending()
                  or l._delivered
                  for l in self.lanes.values()):
            finished.extend(self.poll())
        return finished

    def sync(self) -> None:
        for lane in self.lanes.values():
            lane.sync()

    def close(self) -> None:
        """Shut down the fleet's shared delivery worker (lanes never own
        the stream in fleet mode, so this is the only close point)."""
        if self._shared_stream is not None:
            self._shared_stream.sync()
            self._shared_stream.close()

    # ``run()`` is inherited: submit-all + drain works unchanged because
    # both are overridden here.

    # -- observability ----------------------------------------------------
    @property
    def ticks(self) -> int:
        return sum(l.ticks for l in self.lanes.values())

    @ticks.setter
    def ticks(self, _v):                # pragma: no cover - lanes own ticks
        raise AttributeError("fleet ticks are derived from lane ticks")

    def summary(self, **kw) -> Dict[str, Dict]:
        return {n: l.metrics.summary(**kw) for n, l in self.lanes.items()}

    def conservation(self) -> Dict[str, Dict]:
        return {n: l.metrics.conservation() for n, l in self.lanes.items()}
