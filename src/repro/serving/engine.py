"""Batched serving engine: continuous batching at token granularity.

Every tick advances ALL live slots by one token.  A slot still consuming its
prompt feeds the next prompt token (chunkless "prefill-in-decode"); a slot
past its prompt feeds its last sampled token and records the new one.  Slots
join/leave without recompilation — occupancy is data, not shape — and a
joining request resets its slot's state slice (position, KV validity via
length, recurrent states).

Numerics are pluggable: ``QuantConfig(mode="abfp_ref")`` serves the model
exactly as the AMS device would compute it (the paper's deployment target),
``mode="float"`` is the FLOAT32 reference.  ``mode="abfp_packed"`` is the
production path: all dense weights are quantized ONCE at engine init
(int8 tile codes + bf16 scales, ``models.packing``) and every tick runs the
packed Pallas kernel — no per-token weight re-quantization, half the
weight HBM traffic, and decode-shaped (small-row-block) matmul grids.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.abfp import QuantConfig
from repro.models import decode_step, init_decode_state
from repro.models.layers import Numerics


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_pos: int = 0
    done: bool = False


class ServingEngine:
    def __init__(self, params, mcfg: ModelConfig, *, capacity: int = 8,
                 max_len: int = 512,
                 quant: QuantConfig = QuantConfig(mode="float"),
                 seed: int = 0):
        if quant.mode == "abfp_packed":
            # Quantize-once: pack every dense weight at admission time so
            # the per-tick decode path only streams int8 codes + bf16
            # scales (the paper's program-the-array-once deployment).
            from repro.models.packing import pack_model_params
            params = pack_model_params(params, quant, mcfg)
        self.params = params
        self.mcfg = mcfg
        self.capacity = capacity
        self.max_len = max_len
        self.quant = quant
        self.key = jax.random.PRNGKey(seed)
        self.state = init_decode_state(mcfg, capacity, max_len)
        self.slots: List[Optional[Request]] = [None] * capacity
        self._next_input = np.zeros((capacity,), np.int32)
        self.ticks = 0

        def _step(params, state, token, key):
            nx = Numerics(quant, key)
            return decode_step(params, state, token, mcfg, nx)

        self._jit_step = jax.jit(_step, donate_argnums=(1,))

    # -- slot state reset -----------------------------------------------------
    def _reset_slot(self, i: int):
        def reset(path, leaf):
            names = [str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path]
            b_axis = 1 if "groups" in names else 0
            if leaf.ndim <= b_axis:
                return leaf
            idx = (slice(None),) * b_axis + (i,)
            fill = -1e30 if names[-1] == "m" and leaf.ndim - b_axis == 3 else 0
            return leaf.at[idx].set(fill)

        self.state = jax.tree_util.tree_map_with_path(reset, self.state)

    # -- admission ------------------------------------------------------------
    def try_admit(self, req: Request) -> bool:
        for i, slot in enumerate(self.slots):
            if slot is None:
                self._reset_slot(i)
                self.slots[i] = req
                self._next_input[i] = req.prompt[0]
                req.prompt_pos = 1
                return True
        return False

    # -- one engine tick --------------------------------------------------------
    def step(self):
        if not any(s is not None for s in self.slots):
            return
        token = jnp.asarray(self._next_input)
        self.key, sub = jax.random.split(self.key)
        logits, self.state = self._jit_step(self.params, self.state, token, sub)
        logits = np.asarray(logits, np.float32)
        self.ticks += 1

        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.prompt_pos < len(req.prompt):
                # still prefilling: feed the next prompt token, ignore logits
                self._next_input[i] = req.prompt[req.prompt_pos]
                req.prompt_pos += 1
                continue
            if req.temperature > 0:
                z = logits[i] / req.temperature
                z -= z.max()
                p = np.exp(z)
                p /= p.sum()
                nxt = int(np.random.default_rng(req.uid * 7919 + len(req.generated))
                          .choice(len(p), p=p))
            else:
                nxt = int(np.argmax(logits[i]))
            req.generated.append(nxt)
            self._next_input[i] = nxt
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None            # free for the next request

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a workload to completion (FCFS admission)."""
        pending = list(requests)
        inflight: List[Request] = []
        finished: List[Request] = []
        while pending or inflight:
            while pending and self.try_admit(pending[0]):
                inflight.append(pending.pop(0))
            self.step()
            for r in list(inflight):
                if r.done:
                    inflight.remove(r)
                    finished.append(r)
        return finished
