"""Batched serving engine core: continuous batching with chunked prefill,
arrival-driven admission, and streaming.

Tick model
----------
The engine owns one batched decode state of ``capacity`` slots.  Every call
to ``step()`` advances the batch by ONE jitted pass, which is either:

  * a **decode tick** (``decode_step``) — every live slot advances by one
    token at the decode-specialized matmul shapes (M = capacity), or
  * a **prefill pass** (``models.prefill``) — taken whenever any live slot
    still has unconsumed prompt.  Each prefilling slot contributes its next
    prompt chunk (up to the largest configured bucket) and each DECODING
    slot rides along with its single next token, so admission never stalls
    generation: a prefilling slot and a decoding slot coexist in one batch
    via per-slot position/length tracking (``n_tokens``).

Chunked prefill turns prompt admission from O(prompt_len) sequential
full-model ticks into O(prompt_len / chunk) passes whose matmuls run at
M = capacity * chunk — the MXU-friendly shapes the packed ABFP kernel is
2–5x faster per byte at (see BENCH_serving.json for the measured
time-to-first-token win; ``chunked=False`` restores the legacy
prefill-in-decode behavior for comparison).

Open-loop serving
-----------------
``submit()`` enqueues a request with an ``arrival_time`` (defaulting to the
engine clock "now"); ``poll()`` admits every arrived request the active
scheduling policy picks (``repro.serving.scheduler``: fcfs / sjf /
priority with per-tenant fairness), runs one ``step()``, and returns the
requests that finished during that pass.  The clock is SIMULATED by
default — each jitted pass advances ``tick_time`` — so arrival-driven tests
are fully deterministic; pass ``clock=time.perf_counter`` for wall-clock
serving (the open-loop benchmark does).  When the batch is idle and every
queued request is still in the future, ``poll()`` jumps the simulated
clock to the next arrival instead of burning empty ticks.

Per-request TTFT/TPOT/E2E, tick utilization, and queue depth are recorded
in ``engine.metrics`` (``repro.serving.metrics.ServingMetrics``); each
generated token is also streamed to ``Request.on_token`` the moment it is
sampled.  ``run()`` is a thin closed-loop compatibility wrapper (submit
everything at "now", drain FCFS) and is bit-identical to the historical
static-batch runner for greedy same-seed workloads.

Bucketing policy
----------------
Chunk lengths are drawn from the small static set ``prefill_chunks`` (the
pass is padded up to the smallest bucket that fits, per-slot padding is
masked via ``n_tokens``), so jit compiles at most ``len(prefill_chunks)``
prefill shapes — occupancy, chunk fill, and slot membership are all data,
not shape.

Numerics
--------
Pluggable via ``QuantConfig``: ``mode="abfp_ref"`` serves the model exactly
as the AMS device would compute it (the paper's deployment target),
``mode="float"`` is the FLOAT32 reference.  ``mode="abfp_packed"`` is the
production path: all dense weights are quantized ONCE at engine init
(int8 tile codes + bf16 scales, ``models.packing``) and every pass runs the
packed Pallas kernel — no per-token weight re-quantization, half the weight
HBM traffic.  Float-mode chunked prefill is bit-identical to the token-by-
token path; ABFP modes are statistically equivalent only (the kernel's
noise PRNG salts by grid position, and chunked grids differ from
decode-shaped grids — same noise distribution, different draws).

Sampling: ``temperature == 0`` decodes greedily (argmax); ``temperature >
0`` samples from the temperature-scaled softmax using a stream seeded by
(engine seed, request uid, token index), so draws are reproducible for a
given engine seed regardless of how requests interleave across ticks.

Sharded serving
---------------
``mesh=`` (a ``jax.sharding.Mesh`` with a 'model' axis and optional
'data'/'pod' axes) makes the whole stack mesh-aware: dense weights —
including pre-packed int8 codes + bf16 scales, which shard TOGETHER —
are placed column-parallel over 'model'
(``distributed.sharding.serving_param_spec_tree``), slot state / KV
caches shard over the data axes, and every matmul dispatches through
``kernels.ops.dense_tp`` (shard_map + all-gather, noise salts
globalized per column shard).  Column-parallel splitting never crosses
an ABFP K-tile and never reorders an f32 contraction, so greedy decode
is BIT-IDENTICAL to the single-device engine at any mesh shape, noise
included — the open-loop submit/poll/drain API is unchanged
(tests/test_sharded_serving.py).

Paged KV + overload robustness
------------------------------
``paged=True`` swaps the per-slot ``max_len`` KV strips for a shared
``serving.pages.PagePool``: pages are fixed-size (aligned to the ABFP
tile width so quantized KV scales never straddle a page) and each slot
addresses them through a static-shape page table gathered INSIDE the
jitted pass — allocation churn never recompiles, and float-mode decode
is bit-identical to the unpaged engine.  The host-side table
(``self._table``) is the source of truth and is refreshed into device
state before every pass; unallocated entries hold a sentinel
(``pool.num_pages``) whose writes drop and whose reads clamp, so a dead
slot can never corrupt a live page.  Prefix pages of identical prompts
are shared copy-on-write across requests (chained-hash keys over full
pages; a write to a shared page splits it first).

Under page saturation the engine PREEMPTS the lowest-priority / youngest
slot: its pages return to the pool and the request requeues carrying a
replay of ``prompt + generated``; on re-admission it re-prefills the
replay and continues bit-identically (greedy decode is deterministic, so
recompute IS restore).  Conservation extends to ``preempted == resumed +
timed_out`` per request.  Backpressure sheds newly ARRIVED requests past
``queue_watermark`` (marked ``shed`` with a ``retry_after`` hint,
surfaced through ``poll()``); ``tenant_quota`` caps one tenant's pages at
projected footprint; pool pressure above ``page_watermarks[0]`` flips
hysteretic DEGRADED mode (admissions get ``degraded_max_new``, prefill
drops to the smallest bucket) until pressure falls below
``page_watermarks[1]``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.abfp import QuantConfig
from repro.distributed.fault import StragglerMonitor, plan_recovery_mesh
from repro.serving import faults as faultlib
from repro.serving.faults import FaultConfig, FaultPlan
from repro.serving.metrics import ServingMetrics
from repro.serving.pages import (
    PagePool,
    page_table_array,
    pages_needed,
    plan_chunk,
    prefix_key,
)
from repro.serving.runners import ModelRunner, runner_for
from repro.serving.scheduler import Scheduler, get_scheduler
from repro.serving.stream import (
    DeviceStream,
    OverlappedStream,
    Ticket,
    TokenRec,
)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    arrival_time: Optional[float] = None    # engine clock; None = at submit
    priority: int = 0                       # larger = served first
    tenant: str = "default"                 # fairness domain for `priority`
    model: Optional[str] = None         # fleet routing key (ServingEngine
                                        # with models=...); None on a
                                        # single-model engine
    features: Optional[Any] = None      # frontend side input (enc-dec:
                                        # (enc_len, d_model) frame embeds)
    deadline: Optional[float] = None    # absolute engine-clock time; past it
                                        # the request is cancelled (queued or
                                        # in-flight) and marked timed_out
    on_token: Optional[Callable[["Request", int], None]] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_pos: int = 0                 # prompt tokens consumed so far
    dispatched: int = 0                 # tokens whose pass has been launched
                                        # on device; > len(generated) while
                                        # overlapped deliveries are in flight
    done: bool = False
    timed_out: bool = False             # cancelled by deadline expiry
    replay: Optional[List[int]] = None  # recompute stream after preemption:
                                        # prompt + tokens already streamed,
                                        # re-prefilled verbatim on resume
    preempted: int = 0                  # times evicted under page pressure
    shed: bool = False                  # rejected by admission backpressure
    retry_after: Optional[float] = None  # backoff hint stamped when shed


class ServingEngine:
    def __new__(cls, params=None, mcfg=None, *args, models=None, **kwargs):
        # ``models={name: (params, mcfg[, runner])}`` turns the engine into
        # a multi-model FLEET: one lane (single-model sub-engine) per
        # entry, multiplexed on a shared clock (serving.fleet).
        if models is not None and cls is ServingEngine:
            from repro.serving.fleet import FleetEngine
            return super().__new__(FleetEngine)
        return super().__new__(cls)

    def __init__(self, params, mcfg: ModelConfig, *, capacity: int = 8,
                 max_len: int = 512,
                 runner: Optional[ModelRunner] = None,
                 quant: QuantConfig = QuantConfig(mode="float"),
                 seed: int = 0,
                 prefill_chunks: Sequence[int] = (16, 64, 128),
                 chunked: bool = True,
                 policy: Union[str, Scheduler] = "fcfs",
                 tick_time: float = 1.0,
                 clock: Optional[Callable[[], float]] = None,
                 mesh=None,
                 faults: Optional[Union[FaultConfig, FaultPlan]] = None,
                 recovery: bool = True,
                 detect_every: int = 4,
                 paged: bool = False,
                 page_size: Optional[int] = None,
                 pool_pages: Optional[int] = None,
                 prefix_cache: bool = True,
                 preemption: Optional[bool] = None,
                 queue_watermark: Optional[int] = None,
                 page_watermarks: Tuple[float, float] = (0.85, 0.5),
                 degraded_max_new: Optional[int] = None,
                 tenant_quota: Optional[int] = None,
                 overlap: bool = False,
                 inflight: int = 4,
                 stream: Optional[DeviceStream] = None):
        self.mesh = mesh
        self.runner = runner if runner is not None else runner_for(mcfg)
        if quant.mode in ("abfp_packed", "abfp_fused"):
            # Quantize-once: pack every dense weight at admission time so
            # the per-tick decode path only streams int8 codes + bf16
            # scales (the paper's program-the-array-once deployment).  With
            # a mesh, codes + scales are column-sharded together over the
            # 'model' axis as part of the same one-time step.  abfp_fused
            # additionally bakes per-tile ADC gains into each PackedWeight
            # and routes decode ticks through the fused QKV + attention
            # kernels (kernels.abfp_decode_fused).
            from repro.models.packing import pack_model_params
            params = pack_model_params(params, quant, mcfg, mesh=mesh)
        elif mesh is not None:
            from repro.distributed.sharding import shard_serving_params
            params = shard_serving_params(params, mesh, quant)
        self.params = params
        self.mcfg = mcfg
        self.capacity = capacity
        self.max_len = max_len
        self.quant = quant
        self.seed = seed
        self.key = jax.random.PRNGKey(seed)
        self.prefill_chunks = tuple(sorted({int(c) for c in prefill_chunks}))
        self.chunked = chunked and bool(self.prefill_chunks)

        # -- paged KV pool (serving.pages) ---------------------------------
        # With ``paged=False`` the engine allocates the legacy per-slot
        # max_len caches and NOTHING below exists on the hot path.
        self.paged = bool(paged)
        self.pool: Optional[PagePool] = None
        self.page_size = 0
        self.max_pages = 0
        if self.paged:
            if not self.runner.paged_ok:
                raise ValueError(
                    "paged serving needs append-only full-attention KV "
                    f"caches; got attention_type={mcfg.attention_type!r} "
                    f"({type(self.runner).__name__})")
            # ABFP tile width is the natural page quantum: the paper's
            # fixed-size analog tiles align with the int8 cache blocks.
            self.page_size = int(page_size) if page_size else (
                quant.tile_width if quant.mode != "float"
                else min(16, max_len))
            self.max_pages = pages_needed(max_len, self.page_size)
            self.pool = PagePool(
                int(pool_pages) if pool_pages else capacity * self.max_pages,
                self.page_size)
            self._table = page_table_array(capacity, self.max_pages,
                                           self.pool.sentinel)
            self._slot_pages: List[List[int]] = [[] for _ in range(capacity)]
            self._slot_len = [0] * capacity     # tokens appended per slot
            self._slot_keys: List[List[int]] = [[] for _ in range(capacity)]
            self._slot_cap: List[Optional[int]] = [None] * capacity
        self.prefix_enabled = (self.paged and bool(prefix_cache)
                               and self.chunked
                               and self.runner.prefix_cache_ok)
        self.preemption = self.paged if preemption is None else bool(preemption)
        self.queue_watermark = queue_watermark
        hi, lo = page_watermarks
        assert 0.0 < lo <= hi <= 1.0, "page_watermarks must be (hi, lo) in (0,1]"
        self.page_watermarks = (float(hi), float(lo))
        self.degraded_max_new = degraded_max_new
        self.tenant_quota = tenant_quota
        self._degraded = False

        self.state = self.runner.init_state(
            capacity, max_len,
            page_size=self.page_size if self.paged else None,
            pool_pages=self.pool.num_pages if self.paged else None)
        if mesh is not None:
            # Slot state / KV caches shard over the data axes (slot = batch
            # row); everything stays replicated over 'model' so the
            # column-parallel matmul dispatch keeps results bit-identical
            # to single-device at any mesh shape.
            self.state = self.runner.shard_state(self.state, mesh)
        self.slots: List[Optional[Request]] = [None] * capacity
        self._next_input = np.zeros((capacity,), np.int32)

        # -- overlapped runtime (serving.stream) ---------------------------
        # overlap=False keeps the historical blocking tick: every pass
        # host-syncs through a DeviceStream (inline fetch), and the
        # simulated-clock path is bit-identical to the pre-stream engine.
        # overlap=True (wall clock only) dispatches ahead: sampling runs
        # ON DEVICE inside the jitted pass, the host tracks token COUNTS
        # (`Request.dispatched`) without values, and a background worker
        # resolves each pass's sampled tokens, fires streaming callbacks,
        # and finalizes metrics while the next pass is already running.
        self.overlap = bool(overlap)
        if self.overlap and clock is None:
            raise ValueError(
                "overlap=True needs a wall clock (clock=time.perf_counter): "
                "the simulated clock is defined by blocking passes")
        self._perf = time.perf_counter  # injectable for deterministic tests
        self._owns_stream = stream is None
        self._stream: DeviceStream = stream if stream is not None else (
            OverlappedStream(depth=inflight) if self.overlap
            else DeviceStream())
        self._delivered: deque = deque()    # finished by the worker,
                                            # flushed into poll() returns
        self._dev_next = None               # previous pass's device samples
        self._ov_vals = np.zeros((capacity,), np.int32)
        self._ov_mask = np.zeros((capacity,), bool)

        self.ticks = 0
        self.scheduler = get_scheduler(policy)
        self.metrics = ServingMetrics(capacity)
        self.tick_time = float(tick_time)
        self._clock = clock             # None => simulated (tick_time/pass)
        self.now = clock() if clock is not None else 0.0
        self._just_finished: List[Request] = []
        self._returned: List[Request] = []  # finalized outside step():
                                            # shed + admission-pass expiries
        self._has_deadlines = False     # set on first deadline'd request

        # Wall-clock tick monitoring: every jitted pass's host-visible
        # duration feeds the trailing-median straggler model; escalation
        # state (log -> reslice -> remesh) surfaces in metrics.summary().
        self.straggler = StragglerMonitor()
        self.metrics.straggler = self.straggler

        # -- fault tolerance (serving.faults) ------------------------------
        # With ``faults=None`` nothing below exists on the hot path: the
        # params, jitted functions, and per-tick flow are identical to a
        # build without fault machinery (zero-overhead guarantee,
        # parity-tested in tests/test_faults.py).
        self.recovery = recovery
        self.detect_every = max(1, int(detect_every))
        self._fault_cursor = 0
        self._lost_shard: Optional[int] = None
        self._fault_dirty = False       # unrepaired injected faults active
        if isinstance(faults, FaultConfig):
            from repro.kernels.ops import tp_size
            faults = faultlib.make_fault_plan(self.params, faults,
                                              tp=tp_size(mesh))
        self.fault_plan: Optional[FaultPlan] = faults
        if self.fault_plan is not None:
            # Clean copy = the replicated hot spare the repairs re-program
            # from (a reference, not a copy: injection replaces arrays).
            self._params_clean = self.params
            self._fault_sites = faultlib.fault_sites(self.params)
            self._baselines = faultlib.fingerprint_baselines(self.params)

        self._build_jitted()

    def _build_jitted(self):
        """(Re)build the jitted step/prefill/reset closures for the current
        mesh — called at init and again after a shard-drop re-shard.  The
        closures themselves come from the runner (the model-family seam);
        the engine owns only jit + donation policy.

        Step and prefill are built in their SAMPLED form (the runner wraps
        the same core body either way): every pass returns ``(logits,
        sampled, new_state)`` with next-token sampling on device, so the
        blocking and overlapped paths share one closure and one compile —
        the blocking path simply fetches logits and keeps the host
        sampler, bit-identically to the pre-stream engine."""
        r = self.runner
        self._jit_step = jax.jit(
            r.make_step(self.quant, self.mesh, seed=self.seed),
            donate_argnums=(1,))
        # One compile per chunk bucket (shape-specialized), nothing more.
        self._jit_prefill = jax.jit(
            r.make_prefill(self.quant, self.mesh, seed=self.seed),
            donate_argnums=(1,))
        # Per-shape warmed executables + warmup bookkeeping: a reshard
        # invalidates every compiled shape (new mesh, new shardings).
        self._cached_pref = {}
        self._warmed_shapes = set()
        self._dev_next = None
        # Compile-once slot reset: the slot index is data, so admission
        # under churn costs one fused scatter pass instead of a host-side
        # state rebuild that scales with model size.
        self._jit_reset = jax.jit(r.make_reset(), donate_argnums=(0,))
        self._jit_attach = jax.jit(r.make_attach(), donate_argnums=(0,))
        self._jit_copy_page = jax.jit(r.make_copy_page(), donate_argnums=(0,))
        self._jit_admit = None
        if r.needs_admission:
            self._jit_admit = jax.jit(r.make_admit(self.quant, self.mesh),
                                      donate_argnums=(1,))

    # -- warmed executables -----------------------------------------------
    def _executable(self, shape_key: Tuple, args: Tuple):
        """The ``_cached_pref`` map: one AOT-compiled executable per jit
        shape — ``("decode",)`` or ``("prefill", bucket)`` — compiled (via
        ``jit(...).lower(args).compile()``) OUTSIDE the timed region, so a
        cold bucket's compile never lands in a straggler sample or a
        utilization span.  Returns ``(fn, warmup)``; ``warmup`` marks the
        first EXECUTION of this shape, which the caller excludes from the
        straggler model (first-run dispatch overhead is not a straggler
        signal — see StragglerMonitor)."""
        fn = self._cached_pref.get(shape_key)
        if fn is None:
            base = (self._jit_step if shape_key[0] == "decode"
                    else self._jit_prefill)
            try:
                fn = base.lower(*args).compile()
            except Exception:
                # AOT lowering is best-effort (exotic runner states);
                # falling back to plain jit dispatch keeps serving correct,
                # at worst paying compile inside the first timed pass.
                fn = base
            self._cached_pref[shape_key] = fn
        warm = shape_key not in self._warmed_shapes
        self._warmed_shapes.add(shape_key)
        return fn, warm

    def warmup(self):
        """Pre-compile the decode tick and every prefill bucket so no
        compile happens once traffic is live (benchmarks call this before
        the timed window; a cold engine self-warms lazily through
        ``_executable`` instead)."""
        self._executable(("decode",), self._decode_proto())
        if self.chunked:
            for bucket in self.prefill_chunks:
                self._executable(("prefill", bucket),
                                 self._prefill_proto(bucket))
        # Pre-compiling must not mark shapes as executed: the first REAL
        # pass per shape still carries first-dispatch overhead.
        self._warmed_shapes.clear()

    def _call(self, shape_key: Tuple, args: Tuple):
        """Dispatch one pass through the warmed-executable cache.  If the
        AOT executable rejects the concrete arguments (e.g. a sharding
        lowered from a host prototype disagreeing with a live device
        array), fall back to plain jit dispatch for that shape — correct
        either way, the cache is an optimization."""
        fn, warm = self._executable(shape_key, args)
        try:
            return fn(*args), warm
        except Exception:
            base = (self._jit_step if shape_key[0] == "decode"
                    else self._jit_prefill)
            if fn is base:
                raise
            self._cached_pref[shape_key] = base
            return base(*args), warm

    # -- dispatch inputs --------------------------------------------------
    def _samp_arrays(self):
        """Per-slot sampling inputs for the on-device sampler: temperature,
        uid, and NEXT token index (``dispatched``, which in overlap mode
        runs ahead of ``len(generated)``) — zeros for empty slots."""
        temps = np.zeros((self.capacity,), np.float32)
        uids = np.zeros((self.capacity,), np.int32)
        idxs = np.zeros((self.capacity,), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None:
                temps[i] = req.temperature
                uids[i] = req.uid & 0x7FFFFFFF
                idxs[i] = req.dispatched
        return temps, uids, idxs

    def _decode_proto(self) -> Tuple:
        """Zero-valued decode-tick arguments (lowering prototypes only)."""
        z = np.zeros
        c = self.capacity
        return (self.params, self.state, z((c,), np.int32), z((c,), np.int32),
                z((c,), bool), self.key, z((c,), np.float32),
                z((c,), np.int32), z((c,), np.int32))

    def _prefill_proto(self, bucket: int) -> Tuple:
        """Zero-valued prefill-pass arguments for one chunk bucket."""
        z = np.zeros
        c = self.capacity
        return (self.params, self.state, z((c, bucket), np.int32),
                z((c,), np.int32), z((c,), np.int32), z((c,), bool),
                self.key, z((c,), np.float32), z((c,), np.int32),
                z((c,), np.int32))

    def _set_next(self, i: int, val: int):
        """Host-known next input for slot i.  The blocking path reads it
        from ``_next_input``; the overlapped path additionally records it
        as an OVERRIDE (``ov_mask``) because the base decode input there is
        the previous pass's device sample, which a host prompt feed must
        shadow."""
        self._next_input[i] = int(val)
        if self.overlap:
            self._ov_vals[i] = int(val)
            self._ov_mask[i] = True

    def _clear_ov(self, i: int):
        self._ov_vals[i] = 0
        self._ov_mask[i] = False

    # -- delivery (the stream's consumer side) ----------------------------
    def _account_dispatch(self, i: int, req: Request) -> TokenRec:
        """Host bookkeeping for one on-device sampled token the overlapped
        path has NOT seen yet: bump the dispatched count and, when it hits
        the request's limit, free the slot immediately — completion is a
        COUNT property, so the next admission can reuse the slot while the
        final token is still in flight.  (Device passes execute in
        dispatch order, so pages released here cannot be overwritten
        before this pass's writes land.)"""
        req.dispatched += 1
        limit = req.max_new_tokens
        if self.paged and self._slot_cap[i] is not None:
            limit = min(limit, self._slot_cap[i])
        finishing = req.dispatched >= limit
        if finishing:
            self.slots[i] = None
            self._release_slot(i, req.tenant)
        return TokenRec(slot=i, req=req, finishing=finishing,
                        corrupted=self._fault_dirty)

    def _deliver_ticket(self, ticket: Ticket):
        """Resolve one dispatched pass (runs on the stream's worker thread
        in overlap mode): fetch the (B,) sampled tokens — the ONLY
        device->host transfer on the overlapped hot path — append values,
        fire streaming callbacks, finalize metrics, and feed the
        straggler/utilization gauges."""
        vals = self._stream.fetch(ticket.sampled)
        done = self._perf()
        self.metrics.on_device_span(ticket.t0, done)
        if not ticket.warmup:
            self.straggler.observe(done - ticket.t0)
        for rec in ticket.recs:
            req = rec.req
            nxt = int(vals[rec.slot])
            req.generated.append(nxt)
            self.metrics.on_token(req.uid, ticket.now)
            if rec.corrupted:
                self.metrics.on_corrupted(req.uid)
            if req.on_token is not None:
                req.on_token(req, nxt)
            if rec.finishing:
                req.done = True
                self.metrics.on_finish(req.uid, ticket.now)
                self._delivered.append(req)

    def _drain_delivered(self) -> List[Request]:
        out: List[Request] = []
        while self._delivered:
            out.append(self._delivered.popleft())
        return out

    def sync(self):
        """Wait until every in-flight pass has delivered its tokens
        (no-op on the blocking path).  Called internally before anything
        that must observe COMPLETE token streams: preemption replay
        snapshots, deadline expiry, fault requeues, reshards."""
        self._stream.sync()

    def close(self):
        """Shut down the background delivery worker.  Safe on any engine;
        an engine sharing a fleet-owned stream leaves it to the fleet."""
        if self._owns_stream:
            self._stream.sync()
            self._stream.close()

    # -- clock ----------------------------------------------------------------
    def _tick_clock(self):
        """One jitted pass just ran: advance the engine clock (simulated
        ticks or wall time) BEFORE tokens from that pass are recorded."""
        self.ticks += 1
        self.now = (self._clock() if self._clock is not None
                    else self.now + self.tick_time)

    # -- slot state reset -------------------------------------------------
    def _reset_slot(self, i: int):
        self.state = self._jit_reset(self.state, jnp.int32(i))

    # -- admission ------------------------------------------------------------
    def _feed(self, req: Request) -> List[int]:
        """The token stream this request prefills from: the preemption
        replay snapshot (prompt + tokens already streamed) when resuming a
        recompute, else the prompt."""
        return req.replay if req.replay is not None else req.prompt

    def fits(self, req: Request) -> bool:
        """A request needs a non-empty prompt (there is no token to condition
        the first generation on otherwise) and must leave room for at least
        one generated token — the chunk scatter parks padding lanes on the
        next unwritten cache slot, which only exists while
        length + n_tokens < max_len.

        Under paging the legacy ``prompt + max_new <= max_len`` hard bound
        relaxes to a PAGE-BUDGET check: a long request is admissible iff
        the page table can address it and the pool (at full eviction) could
        grow it — the pool serves worst cases that per-slot allocation
        would have to reserve for everyone."""
        if len(req.prompt) < 1:
            return False
        total = len(req.prompt) + max(1, req.max_new_tokens)
        if not self.paged:
            # Fixed-state runners (recurrent families) hold O(1) decode
            # state per slot — sequence length never hits a cache bound.
            return self.runner.fixed_state or total <= self.max_len
        need = self.runner.capacity_cost(total, self.page_size)
        return need <= self.max_pages and need <= self.pool.num_pages

    def _should_shed(self, req: Request, at: float) -> bool:
        """Admission backpressure for requests arriving NOW: shed when the
        queue is past its watermark, or when the pool is past the high
        pressure watermark AND the queue already covers the batch."""
        if (self.queue_watermark is not None
                and self.scheduler.pending(at) >= self.queue_watermark):
            return True
        if (self.paged and self.pool.pressure() >= self.page_watermarks[0]
                and self.scheduler.pending(at) >= self.capacity):
            return True
        return False

    def _retry_after(self, at: float) -> float:
        """Absolute engine-clock time the shed client should retry at:
        backlog / capacity service rounds at the observed mean E2E (or a
        few ticks before any request has finished)."""
        fin = [r.e2e for r in self.metrics.finished() if r.e2e is not None]
        est = float(np.mean(fin)) if fin else self.tick_time * 8
        backlog = self.scheduler.pending(at) + sum(
            1 for s in self.slots if s is not None)
        return at + est * max(1.0, backlog / max(1, self.capacity))

    def submit(self, req: Request) -> bool:
        """Enqueue a request for arrival-driven admission.  Stamps
        ``arrival_time`` with the current clock when unset.  Oversized
        requests are rejected (marked done, recorded in metrics) instead of
        crashing the serve loop; under backpressure watermarks an arriving
        request is SHED instead of queued (``req.shed`` with a
        ``req.retry_after`` hint, surfaced through the next ``poll()``).
        Returns False for both."""
        if not self.fits(req) or not self.runner.accepts(req):
            req.done = True
            self.metrics.on_reject(req.uid)
            return False
        if req.arrival_time is None:
            req.arrival_time = self.now
        if req.arrival_time <= self.now and self._should_shed(
                req, req.arrival_time):
            req.done = True
            req.shed = True
            req.retry_after = self._retry_after(req.arrival_time)
            self.metrics.on_shed(req.uid, tenant=req.tenant,
                                 retry_after=req.retry_after)
            self._returned.append(req)
            return False
        if req.deadline is not None:
            self._has_deadlines = True
        self.metrics.on_submit(req.uid, arrival_time=req.arrival_time,
                               tenant=req.tenant,
                               prompt_len=len(req.prompt))
        self.scheduler.add(req)
        return True

    def try_admit(self, req: Request) -> bool:
        if not self.fits(req):
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) must be "
                f"non-empty and prompt + max_new ({req.max_new_tokens}) "
                f"must fit max_len ({self.max_len})")
        for i, slot in enumerate(self.slots):
            if slot is None:
                self._reset_slot(i)
                self._clear_ov(i)   # stale override from a past occupant
                self.slots[i] = req
                if req.arrival_time is None:
                    req.arrival_time = self.now
                if req.deadline is not None:
                    self._has_deadlines = True
                self.metrics.on_admit(req.uid, self.now, tenant=req.tenant,
                                      prompt_len=len(req.prompt),
                                      arrival_time=req.arrival_time)
                if self.paged:
                    self._table[i, :] = self.pool.sentinel
                    self._slot_pages[i] = []
                    self._slot_len[i] = 0
                    self._slot_keys[i] = []
                    # Degraded mode caps generation for admissions made
                    # under pressure (never below what a resumed request
                    # already streamed).
                    self._slot_cap[i] = None
                    if self._degraded and self.degraded_max_new is not None:
                        self._slot_cap[i] = max(self.degraded_max_new,
                                                len(req.generated) + 1)
                if self._jit_admit is not None:
                    # Runner admission hook (enc-dec: one encoder pass whose
                    # cross-attention KV is cached in this slot for the whole
                    # request).  Keyed off the request uid so a preemption
                    # replay re-encodes to bit-identical features.
                    akey = jax.random.fold_in(
                        jax.random.PRNGKey(self.seed), req.uid)
                    self.state = self._jit_admit(
                        self.params, self.state,
                        jnp.asarray(req.features), jnp.int32(i), akey)
                toks = self._feed(req)
                if self.chunked:
                    req.prompt_pos = 0      # consumed by prefill passes
                    if self.prefix_enabled:
                        self._attach_prefix(i, req)
                else:
                    # Legacy prefill-in-decode: one prompt token per tick.
                    self._set_next(i, toks[0])
                    req.prompt_pos = 1
                return True
        return False

    def _admissible(self, req: Request) -> bool:
        """Pop-time admission filter: per-tenant page quota (noisy-neighbor
        isolation) and basic pool availability.  Requests failing it are
        SKIPPED, not dequeued, so one greedy tenant never head-of-line
        blocks the rest of the queue."""
        if not self.paged:
            return True
        return self._quota_ok(req) and self.pool.available() >= 1

    def _quota_ok(self, req: Request) -> bool:
        """Per-tenant page quota, checked against PROJECTED footprints.
        Pages are allocated lazily per prefill chunk, so gating on current
        holdings alone would let a tenant admit several requests "under
        quota" in one pass and then grow all of them past it; instead each
        live same-tenant slot is charged its full eventual footprint.  A
        tenant with nothing in flight always passes — a quota can throttle
        a tenant, never starve it outright.  Also the quota-only filter
        for the priority-claim path, where page availability is what
        preemption is about to create."""
        if self.tenant_quota is None or self.pool is None:
            return True
        live = [r for r in self.slots
                if r is not None and r.tenant == req.tenant]
        if not live and self.pool.tenant_held(req.tenant) == 0:
            return True
        charged = sum(
            self.runner.capacity_cost(
                len(r.prompt) + max(1, r.max_new_tokens), self.page_size)
            for r in live)
        remaining = max(1, req.max_new_tokens - len(req.generated))
        need = self.runner.capacity_cost(
            len(self._feed(req)) + remaining, self.page_size)
        return charged + need <= self.tenant_quota

    def _admit_arrived(self) -> List[Request]:
        """Fill free slots from the scheduler queue (policy order) with
        requests that have arrived by the current clock.

        Queue expiry runs FIRST: a request requeued (by fault recovery or
        preemption) whose deadline has since passed must be timed out here,
        never re-admitted — its expiry is surfaced through the same poll
        that would have admitted it."""
        if self._has_deadlines:
            self._returned.extend(self._expire_queue())
        admitted: List[Request] = []
        free = self.slots.count(None)
        while free > 0:
            req = self.scheduler.pop(
                self.now, self._admissible if self.paged else None)
            if req is None:
                break
            self.try_admit(req)     # a slot is free; fits() held at submit
            admitted.append(req)
            free -= 1
        if self.paged and self.preemption:
            self._priority_claim(admitted)
        return admitted

    def _priority_claim(self, admitted: List[Request]):
        """Under saturation, a strictly-higher-priority arrival claims a
        slot (and its pages) by preempting the lowest-priority live
        request; ties and lower priorities wait their turn."""
        while True:
            top = self.scheduler.peek(self.now, self._quota_ok)
            if top is None:
                return
            if self.slots.count(None) and self.pool.available() >= 1:
                return              # normal admission will take it
            victims = [i for i, s in enumerate(self.slots)
                       if s is not None and s.priority < top.priority]
            if not victims:
                return
            v = min(victims, key=lambda i: (self.slots[i].priority,
                                            -(self.slots[i].arrival_time
                                              or 0.0),
                                            -self.slots[i].uid))
            self._preempt_slot(v)
            self.scheduler.remove(top)
            self.try_admit(top)
            admitted.append(top)

    # -- sampling -------------------------------------------------------------
    def _record(self, i: int, req: Request, logits_row: np.ndarray):
        if req.temperature > 0:
            # Temperature sampling from the engine's seeded stream: the
            # draw is keyed by (engine seed, uid, token index), so outputs
            # are reproducible for a given engine seed no matter how the
            # scheduler interleaves this request with others.
            z = logits_row.astype(np.float64) / req.temperature
            z -= z.max()
            p = np.exp(z)
            p /= p.sum()
            rng = np.random.default_rng(
                (self.seed, req.uid, len(req.generated)))
            nxt = int(rng.choice(len(p), p=p))
        else:
            nxt = int(np.argmax(logits_row))
        req.generated.append(nxt)
        req.dispatched = len(req.generated)
        self._next_input[i] = nxt
        self.metrics.on_token(req.uid, self.now)
        if self._fault_dirty:
            # This token was computed against faulted weights that no
            # detection round has repaired yet: the request's output can't
            # be trusted.  (Cleared if recovery later requeues it.)
            self.metrics.on_corrupted(req.uid)
        if req.on_token is not None:
            req.on_token(req, nxt)
        limit = req.max_new_tokens
        if self.paged and self._slot_cap[i] is not None:
            limit = min(limit, self._slot_cap[i])
        if len(req.generated) >= limit:
            req.done = True
            self.slots[i] = None            # free for the next request
            self._release_slot(i, req.tenant)
            self.metrics.on_finish(req.uid, self.now)
            self._just_finished.append(req)

    # -- paged pool management --------------------------------------------
    def _release_slot(self, i: int, tenant: str):
        """Return slot i's pages to the pool and clear its host mirrors.
        Pages the prefix cache also holds stay allocated for reuse."""
        if not self.paged:
            return
        if self._slot_pages[i]:
            self.pool.release(self._slot_pages[i], tenant)
        self._slot_pages[i] = []
        self._slot_len[i] = 0
        self._slot_keys[i] = []
        self._slot_cap[i] = None
        self._table[i, :] = self.pool.sentinel

    def _preempt_slot(self, i: int):
        """Evict slot i to the queue with a recompute plan: its pages go
        back to the pool NOW, and ``req.replay`` snapshots prompt + every
        token already streamed so the resume prefills the identical stream
        (bit-identical continuation in float mode — re-prefilling the same
        tokens rebuilds the same cache the decode ticks had built)."""
        self.sync()     # the replay snapshot needs every in-flight token
        req = self.slots[i]
        self.slots[i] = None
        self._next_input[i] = 0
        self._clear_ov(i)
        self._release_slot(i, req.tenant)
        req.replay = list(req.prompt) + list(req.generated)
        req.prompt_pos = 0
        req.preempted += 1
        self.metrics.on_preempt(req.uid, self.now)
        self.scheduler.requeue(req)

    def _preempt_for(self, req: Request) -> bool:
        """Free pages for ``req`` by preempting a live victim that does not
        outrank it (strictly lower priority, or same priority but younger).
        Returns False when no such victim exists."""
        cand = [i for i, s in enumerate(self.slots)
                if s is not None and s is not req
                and (s.priority < req.priority
                     or (s.priority == req.priority
                         and (s.arrival_time or 0.0)
                         >= (req.arrival_time or 0.0)))]
        if not cand:
            return False
        v = min(cand, key=lambda i: (self.slots[i].priority,
                                     -(self.slots[i].arrival_time or 0.0),
                                     -self.slots[i].uid))
        self._preempt_slot(v)
        return True

    def _chunk_cap(self) -> int:
        """Largest prefill chunk this tick: degraded mode shrinks the
        bucket to the smallest configured chunk so admission burst memory
        stays bounded while the pool is saturated."""
        if self.paged and self._degraded:
            return self.prefill_chunks[0]
        return self.prefill_chunks[-1] if self.prefill_chunks else 1

    def _update_degraded(self):
        """Hysteretic degraded mode: enter at the high pool-pressure
        watermark, recover only once pressure falls to the low one."""
        hi, lo = self.page_watermarks
        p = self.pool.pressure()
        if not self._degraded and p >= hi:
            self._degraded = True
            self.metrics.on_degraded(True, self.now)
        elif self._degraded and p <= lo:
            self._degraded = False
            self.metrics.on_degraded(False, self.now)

    def _grow_slot(self, i: int, req: Request, need: int) -> bool:
        """Make slot i's next ``need`` token positions writable: CoW-split
        shared pages in the write range, allocate missing pages, and — when
        the pool is dry — preempt non-outranking victims (possibly slot i
        itself, returning False)."""
        extra, writes = plan_chunk(self._slot_len[i], need,
                                   self._slot_pages[i], self.page_size)
        for j in writes:
            p = self._slot_pages[i][j]
            newp = self.pool.cow(p, req.tenant)
            while newp is None:
                if not self._preempt_for(req):
                    self._preempt_slot(i)
                    return False
                newp = self.pool.cow(p, req.tenant)
            if newp != p:
                self.state = self._jit_copy_page(
                    self.state, jnp.int32(p), jnp.int32(newp))
                self._slot_pages[i][j] = newp
                self._table[i, j] = newp
                self.metrics.on_cow()
        while extra > 0:
            got = self.pool.alloc(extra, req.tenant)
            if got is not None:
                base = len(self._slot_pages[i])
                for jj, p in enumerate(got):
                    self._table[i, base + jj] = p
                self._slot_pages[i].extend(got)
                break
            if not self._preempt_for(req):
                self._preempt_slot(i)
                return False
        return True

    def _ensure_pages(self, live: List[int]) -> List[int]:
        """Before a jitted pass, guarantee every live slot owns writable
        pages for the tokens it is about to append — higher-priority /
        older slots claim first, so pool exhaustion preempts the requests
        preemption policy says should yield.  Returns the surviving live
        list."""
        cap = self._chunk_cap()
        order = sorted(live, key=lambda i: (-self.slots[i].priority,
                                            self.slots[i].arrival_time or 0.0,
                                            self.slots[i].uid))
        for i in order:
            req = self.slots[i]
            if req is None:
                continue            # preempted by an earlier claimant
            toks = self._feed(req)
            rem = len(toks) - req.prompt_pos
            need = min(rem, cap) if rem > 0 else 1
            self._grow_slot(i, req, need)
        return [i for i in live if self.slots[i] is not None]

    def _attach_prefix(self, i: int, req: Request):
        """Prefix-cache attach at admission: walk the prompt's full-page
        chain keys through the pool cache; every hit is SHARED (ref++) so
        those pages are never re-prefilled.  When the whole prompt hits, we
        back off one token — the last token re-feeds through the normal
        pass to produce first logits, and its write triggers the CoW split
        of the shared final page."""
        toks = self._feed(req)
        key = None
        matched: List[Tuple[int, int]] = []
        pos = 0
        while pos + self.page_size <= len(toks):
            key = prefix_key(key, toks[pos:pos + self.page_size])
            p = self.pool.lookup(key)
            if p is None:
                break
            matched.append((key, p))
            pos += self.page_size
        if not matched:
            return
        self.pool.share([p for _, p in matched], req.tenant)
        self._slot_pages[i] = [p for _, p in matched]
        self._slot_keys[i] = [k for k, _ in matched]
        for j, (_, p) in enumerate(matched):
            self._table[i, j] = p
        attached = min(pos, len(toks) - 1)
        self._slot_len[i] = attached
        req.prompt_pos = attached
        self.state = self._jit_attach(self.state, jnp.int32(i),
                                      jnp.int32(attached))
        self.metrics.on_prefix(len(matched))

    def _register_prefix(self, i: int, req: Request):
        """Publish slot i's fully-prefilled PROMPT pages under their chain
        keys (fresh requests only — replay streams would poison the cache
        with generated tokens)."""
        if req.replay is not None:
            return
        full = min(req.prompt_pos, len(req.prompt)) // self.page_size
        while len(self._slot_keys[i]) < full:
            j = len(self._slot_keys[i])
            block = req.prompt[j * self.page_size:(j + 1) * self.page_size]
            prev = self._slot_keys[i][-1] if self._slot_keys[i] else None
            key = prefix_key(prev, block)
            self._slot_keys[i].append(key)
            if j < len(self._slot_pages[i]):
                self.pool.register(key, self._slot_pages[i][j])

    # -- deadlines --------------------------------------------------------
    def _expire_slots(self):
        """Cancel in-flight requests past their deadline: free the slot
        immediately (the next admit resets its state) instead of letting a
        stuck request squat until max_new_tokens."""
        for i, req in enumerate(self.slots):
            if (req is not None and req.deadline is not None
                    and req.deadline <= self.now):
                self.slots[i] = None
                self._release_slot(i, req.tenant)
                req.done = True
                req.timed_out = True
                self.metrics.on_timeout(req.uid, self.now)
                self._just_finished.append(req)

    def _expire_queue(self) -> List[Request]:
        """Time out queued requests whose deadline already passed."""
        expired = self.scheduler.expire(self.now)
        for req in expired:
            req.done = True
            req.timed_out = True
            self.metrics.on_timeout(req.uid, self.now)
        return expired

    # -- fault tolerance --------------------------------------------------
    def _inject_due_faults(self):
        """Apply every fault event scheduled at or before the current tick:
        a sharding-preserving rewrite of the packed operands the jitted
        step streams (serving.faults), so the fault flows through
        dense_tp / the packed kernels at any mesh shape."""
        from repro.kernels.ops import tp_size
        due, self._fault_cursor = self.fault_plan.due(
            self.ticks, self._fault_cursor)
        for ev in due:
            if ev.kind == "shard_drop":
                # The injectable host-failure signal distributed.fault
                # documents — recovery reads it as a health-check verdict.
                self._lost_shard = ev.shard
            self.params = faultlib.apply_event(
                self.params, ev, tp=tp_size(self.mesh), quant=self.quant,
                mesh=self.mesh)
            self.metrics.on_fault(ev.kind)
            self._fault_dirty = True

    def _detect_and_recover(self):
        """One detection round: fingerprint-probe every fault site against
        its healthy baseline; with recovery on, repair what was found
        (re-quantize drifted tiles, remap stuck columns, re-shard on a
        lost-shard health signal + requeue its in-flight requests)."""
        self.sync()     # requeues read complete streams + corruption marks
        if self._lost_shard is not None and self.recovery:
            self._reshard_and_requeue()
            return
        hits = []
        for site in self._fault_sites:
            cur = faultlib.site_fingerprint(self.params, site)
            det = faultlib.detect_site(self._baselines[site.path], cur)
            if not det.clean:
                hits.append((site, det))
        if hits:
            self.metrics.on_detected(sum(
                len(d.stuck_cols) + len(d.drifted) for _, d in hits))
        if not self.recovery:
            return
        for site, det in hits:
            if det.stuck_cols:
                self.params = faultlib.repair_stuck(
                    self.params, self._params_clean, site.path,
                    det.stuck_cols)
                self.metrics.on_repair("cols_remapped", len(det.stuck_cols))
            if det.drifted:
                self.params = faultlib.repair_drift(
                    self.params, self._params_clean, site.path, det.drifted)
                self.metrics.on_repair("tiles_requantized", len(det.drifted))
        if hits:
            # Tokens emitted during the dirty window were computed against
            # faulted weights; with recovery on they are DISCARDED and the
            # request re-decoded from the now-clean array (a shipped token
            # is gone, so only in-flight requests can be salvaged).
            self._requeue_corrupted()
        # Everything detectable was just repaired; ticks from here on are
        # clean until the next injection flips the flag back.
        self._fault_dirty = False

    def _requeue_corrupted(self):
        """Restart in-flight requests whose partial output (and KV cache)
        was produced under an active fault: free the slot, clear generated
        tokens, and requeue — arrival order is preserved, so they re-admit
        ahead of younger traffic."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            rec = self.metrics.requests.get(req.uid)
            if rec is None or not rec.corrupted:
                continue
            self.slots[i] = None
            self._next_input[i] = 0
            self._clear_ov(i)
            self._release_slot(i, req.tenant)
            req.prompt_pos = 0
            req.generated.clear()
            req.dispatched = 0
            req.replay = None       # corrupted stream: restart from prompt
            self.metrics.on_requeue(req.uid)
            self.scheduler.requeue(req)

    def _reshard_and_requeue(self):
        """Shard-drop recovery: re-plan the mesh without the lost bank
        (distributed.fault.plan_recovery_mesh), re-program weights from
        the clean master onto the surviving chips, and requeue every
        in-flight request through the scheduler with state reset — the
        lost shard's slot state (KV caches) died with it, but no request
        is ever lost (conservation: submitted == completed + rejected +
        timed_out still holds over the whole trace)."""
        import numpy as onp
        from jax.sharding import Mesh

        from repro.distributed.sharding import shard_serving_params

        self._lost_shard = None
        if self.mesh is not None and self.mesh.devices.size > 1:
            old_shape = tuple(self.mesh.devices.shape)
            dp, tp = old_shape
            # Losing model bank s costs its chip in every data row.
            plan = plan_recovery_mesh(dp * tp - dp, tp, old_shape)
            devices = list(self.mesh.devices.flat)
            keep = devices[: plan.new_shape[0] * plan.new_shape[1]]
            self.mesh = Mesh(
                onp.asarray(keep).reshape(plan.new_shape),
                self.mesh.axis_names)
            self.params = shard_serving_params(
                self._params_clean, self.mesh, self.quant)
            self._params_clean = self.params
            self._build_jitted()        # closures bind the new mesh
            self.state = self.runner.init_state(
                self.capacity, self.max_len,
                page_size=self.page_size if self.paged else None,
                pool_pages=self.pool.num_pages if self.paged else None)
            self.state = self.runner.shard_state(self.state, self.mesh)
        else:
            # Single-array engine: re-program the array from the spare.
            self.params = self._params_clean
            self.state = self.runner.init_state(
                self.capacity, self.max_len,
                page_size=self.page_size if self.paged else None,
                pool_pages=self.pool.num_pages if self.paged else None)
        if self.paged:
            # The lost shard's pool pages died with the state: rebuild the
            # allocator (prefix cache included) from scratch.
            self.pool = PagePool(self.pool.num_pages, self.page_size)
            self._table = page_table_array(self.capacity, self.max_pages,
                                           self.pool.sentinel)
            self._slot_pages = [[] for _ in range(self.capacity)]
            self._slot_len = [0] * self.capacity
            self._slot_keys = [[] for _ in range(self.capacity)]
            self._slot_cap = [None] * self.capacity
        inflight = [r for r in self.slots if r is not None]
        self.slots = [None] * self.capacity
        self._next_input[:] = 0
        self._ov_vals[:] = 0
        self._ov_mask[:] = False
        for req in inflight:
            req.prompt_pos = 0
            req.generated.clear()
            req.dispatched = 0
            req.replay = None
            self.metrics.on_requeue(req.uid)
            self.scheduler.requeue(req)
        self.metrics.on_repair("reshards", 1)
        self._fault_dirty = False

    # -- one engine tick ------------------------------------------------------
    def step(self):
        # Completion flushing happens per pass (not only per poll) so a
        # long-lived engine driven through the legacy try_admit()/step()
        # path never accumulates finished Request objects.
        self._just_finished = []
        if self._has_deadlines:
            if self.overlap:
                self.sync()     # cancel only COMPLETE streams
            self._expire_slots()
            self._just_finished.extend(self._expire_queue())
        if self.fault_plan is not None:
            # Detect (and repair) faults from earlier ticks BEFORE this
            # tick's injections land, so every fault is live for at least
            # one pass — then inject whatever the plan schedules now.
            if self.ticks % self.detect_every == 0 and (
                    self._fault_dirty or self._lost_shard is not None):
                self._detect_and_recover()
            self._inject_due_faults()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if self.paged:
            self._update_degraded()
            if live:
                # Claim/CoW/grow pages for every token this pass appends;
                # pool exhaustion preempts here, before the jitted call.
                live = self._ensure_pages(live)
        if not live:
            return
        self.metrics.on_tick(self.now, len(live), self.capacity,
                             self.scheduler.pending(self.now),
                             pool=self.pool.stats() if self.paged else None,
                             degraded=self._degraded)
        prefilling = [i for i in live
                      if self.slots[i].prompt_pos
                      < len(self._feed(self.slots[i]))]
        if self.chunked and prefilling:
            if all(len(self._feed(self.slots[i])) - self.slots[i].prompt_pos
                   == 1 for i in prefilling):
                # Every prefilling slot has exactly ONE prompt token left:
                # the decode tick already has the right shape, so feed that
                # token as the decode input instead of paying a padded
                # smallest-bucket chunk pass.
                for i in prefilling:
                    req = self.slots[i]
                    self._set_next(i, self._feed(req)[req.prompt_pos])
                    req.prompt_pos += 1
                self._decode_tick()
            else:
                self._prefill_pass(live)
        else:
            self._decode_tick()

    def _prefill_pass(self, live: List[int]):
        """One bucketed prefill pass: prompt chunks for prefilling slots,
        a single next token for decoding slots, no-op for empty slots.

        Decoding slots riding along take their input from ``_next_input``
        on the blocking path, or from the previous pass's on-device sample
        (``rider_mask``) on the overlapped path — unless a host override is
        pending (preemption zeroing, legacy feeds), which wins either way.
        """
        cap = self._chunk_cap()
        need = np.zeros((self.capacity,), np.int32)
        for i in live:
            req = self.slots[i]
            rem = len(self._feed(req)) - req.prompt_pos
            need[i] = min(rem, cap) if rem > 0 else 1
        bucket = next(c for c in self.prefill_chunks if c >= need.max())

        tokens = np.zeros((self.capacity, bucket), np.int32)
        riders = np.zeros((self.capacity,), bool)
        for i in live:
            req = self.slots[i]
            toks = self._feed(req)
            if req.prompt_pos < len(toks):
                n = int(need[i])
                tokens[i, :n] = toks[req.prompt_pos:req.prompt_pos + n]
            elif (self.overlap and self._dev_next is not None
                    and not self._ov_mask[i]):
                riders[i] = True    # input = previous device sample
            else:
                tokens[i, 0] = self._next_input[i]
        if self.paged:
            self.state["page_table"] = jnp.asarray(self._table)
        temps, uids, idxs = self._samp_arrays()
        self.key, sub = jax.random.split(self.key)
        rv = (self._dev_next if self._dev_next is not None
              else np.zeros((self.capacity,), np.int32))
        args = (self.params, self.state, tokens, need, rv, riders, sub,
                temps, uids, idxs)
        t0 = self._perf()
        self.metrics.window_open(t0)
        (logits, sampled, self.state), warm = self._call(
            ("prefill", bucket), args)
        self._dev_next = sampled
        self._ov_vals[:] = 0
        self._ov_mask[:] = False

        # Recipients: slots whose prompt completes this pass, or decode
        # riders — exactly the slots _record would have sampled for.
        recipients = [
            i for i in live
            if (len(self._feed(self.slots[i])) - self.slots[i].prompt_pos
                <= int(need[i]))]

        if not self.overlap:
            lg = None
            if recipients:
                lg = self._stream.fetch(logits, np.float32)  # host sync
                done = self._perf()
                self.metrics.on_device_span(t0, done)
                if not warm:
                    self.straggler.observe(done - t0)
            self._tick_clock()
            if self.paged:
                for i in live:
                    self._slot_len[i] += int(need[i])
            for i in live:
                req = self.slots[i]
                toks = self._feed(req)
                if req.prompt_pos < len(toks):
                    req.prompt_pos += int(need[i])
                    if self.prefix_enabled:
                        self._register_prefix(i, req)
                    if req.prompt_pos < len(toks):
                        continue        # still prefilling; logits unused
                # Prompt just completed (logits are at its last prompt
                # token) or the slot was decoding: sample either way.
                self._record(i, req, lg[i])
            return

        self._tick_clock()
        if self.paged:
            for i in live:
                self._slot_len[i] += int(need[i])
        recs: List[TokenRec] = []
        for i in live:
            req = self.slots[i]
            toks = self._feed(req)
            if req.prompt_pos < len(toks):
                req.prompt_pos += int(need[i])
                if self.prefix_enabled:
                    self._register_prefix(i, req)
                if req.prompt_pos < len(toks):
                    continue
            recs.append(self._account_dispatch(i, req))
        self._stream.submit(Ticket(engine=self, t0=t0, warmup=warm,
                                   sampled=sampled, recs=recs, now=self.now))

    def _decode_tick(self):
        if self.paged:
            self.state["page_table"] = jnp.asarray(self._table)
        fed = [i for i, s in enumerate(self.slots) if s is not None]
        token = (self._dev_next
                 if self.overlap and self._dev_next is not None
                 else self._next_input)
        ov_vals, ov_mask = self._ov_vals.copy(), self._ov_mask.copy()
        temps, uids, idxs = self._samp_arrays()
        self.key, sub = jax.random.split(self.key)
        args = (self.params, self.state, token, ov_vals, ov_mask, sub,
                temps, uids, idxs)
        t0 = self._perf()
        self.metrics.window_open(t0)
        (logits, sampled, self.state), warm = self._call(("decode",), args)
        self._dev_next = sampled
        self._ov_vals[:] = 0
        self._ov_mask[:] = False

        recipients = [i for i in fed
                      if self.slots[i].prompt_pos
                      >= len(self._feed(self.slots[i]))]

        if not self.overlap:
            lg = None
            if recipients:
                lg = self._stream.fetch(logits, np.float32)  # host sync
                done = self._perf()
                self.metrics.on_device_span(t0, done)
                if not warm:
                    self.straggler.observe(done - t0)
            self._tick_clock()
            if self.paged:
                for i in fed:
                    self._slot_len[i] += 1
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                toks = self._feed(req)
                if req.prompt_pos < len(toks):
                    # legacy prefill-in-decode: feed the next prompt token
                    self._set_next(i, toks[req.prompt_pos])
                    req.prompt_pos += 1
                    continue
                self._record(i, req, lg[i])
            return

        self._tick_clock()
        if self.paged:
            for i in fed:
                self._slot_len[i] += 1
        recs: List[TokenRec] = []
        for i in list(fed):
            req = self.slots[i]
            if req is None:
                continue
            toks = self._feed(req)
            if req.prompt_pos < len(toks):
                self._set_next(i, toks[req.prompt_pos])
                req.prompt_pos += 1
                continue
            recs.append(self._account_dispatch(i, req))
        self._stream.submit(Ticket(engine=self, t0=t0, warmup=warm,
                                   sampled=sampled, recs=recs, now=self.now))

    # -- open-loop API ----------------------------------------------------
    def poll(self) -> List[Request]:
        """One arrival-driven engine round: sync the clock, admit every
        arrived request the policy picks, run one ``step()``.  Returns the
        requests that FINISHED during this poll (possibly empty) plus any
        requests finalized OUTSIDE a step since the last poll: shed
        submissions (``req.shed`` with a ``retry_after`` hint) and queued
        requests whose deadline passed during an admission pass.  With the
        simulated clock an idle engine jumps straight to the next arrival;
        with a real clock it returns immediately and the caller re-polls."""
        if self._clock is not None:
            self.now = self._clock()
        out = self._returned
        self._returned = []
        out.extend(self._drain_delivered())
        self._admit_arrived()
        if all(s is None for s in self.slots):
            if self._stream.pending():
                # Overlap: everything dispatched, nothing left to feed —
                # wait for in-flight deliveries (they may finish requests
                # or fire callbacks that submit new ones).
                self._stream.sync()
                out.extend(self._drain_delivered())
            self.metrics.window_close(self._perf())
            nxt = self.scheduler.next_arrival()
            if nxt is None:
                return out                  # fully drained
            if self._clock is not None:
                # Real time hasn't caught up to the next arrival: nap
                # (capped) instead of letting drain() busy-spin a core
                # through the inter-arrival gap.  Re-sync the clock after
                # the nap — otherwise the next admission pass stamps
                # queue-delay against a ``now`` from before the sleep.
                if nxt > self.now:
                    time.sleep(min(nxt - self.now, 0.01))
                    self.now = self._clock()
                return out
            self.now = max(self.now, nxt)
            self._admit_arrived()
        self.step()
        return out + list(self._just_finished)

    def drain(self) -> List[Request]:
        """Poll until the queue, every slot, the in-flight stream, and the
        returned buffer are empty; returns finished requests in completion
        order."""
        finished: List[Request] = []
        while (len(self.scheduler)
               or any(s is not None for s in self.slots)
               or self._returned
               or self._stream.pending()
               or self._delivered):
            finished.extend(self.poll())
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        """Closed-loop compatibility wrapper: serve a static workload to
        completion under the engine's policy (FCFS by default, matching the
        historical behavior bit-for-bit for greedy same-seed workloads).
        Oversized requests are rejected up front (marked done, nothing
        generated) rather than crashing the serve loop mid-flight; SHED
        requests surface through drain()'s polls, not here, so nothing is
        returned twice."""
        finished: List[Request] = []
        for r in requests:
            if not self.submit(r) and not r.shed:
                finished.append(r)
        finished.extend(self.drain())
        return finished
