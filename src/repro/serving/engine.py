"""Batched serving engine core: continuous batching with chunked prefill,
arrival-driven admission, and streaming.

Tick model
----------
The engine owns one batched decode state of ``capacity`` slots.  Every call
to ``step()`` advances the batch by ONE jitted pass, which is either:

  * a **decode tick** (``decode_step``) — every live slot advances by one
    token at the decode-specialized matmul shapes (M = capacity), or
  * a **prefill pass** (``models.prefill``) — taken whenever any live slot
    still has unconsumed prompt.  Each prefilling slot contributes its next
    prompt chunk (up to the largest configured bucket) and each DECODING
    slot rides along with its single next token, so admission never stalls
    generation: a prefilling slot and a decoding slot coexist in one batch
    via per-slot position/length tracking (``n_tokens``).

Chunked prefill turns prompt admission from O(prompt_len) sequential
full-model ticks into O(prompt_len / chunk) passes whose matmuls run at
M = capacity * chunk — the MXU-friendly shapes the packed ABFP kernel is
2–5x faster per byte at (see BENCH_serving.json for the measured
time-to-first-token win; ``chunked=False`` restores the legacy
prefill-in-decode behavior for comparison).

Open-loop serving
-----------------
``submit()`` enqueues a request with an ``arrival_time`` (defaulting to the
engine clock "now"); ``poll()`` admits every arrived request the active
scheduling policy picks (``repro.serving.scheduler``: fcfs / sjf /
priority with per-tenant fairness), runs one ``step()``, and returns the
requests that finished during that pass.  The clock is SIMULATED by
default — each jitted pass advances ``tick_time`` — so arrival-driven tests
are fully deterministic; pass ``clock=time.perf_counter`` for wall-clock
serving (the open-loop benchmark does).  When the batch is idle and every
queued request is still in the future, ``poll()`` jumps the simulated
clock to the next arrival instead of burning empty ticks.

Per-request TTFT/TPOT/E2E, tick utilization, and queue depth are recorded
in ``engine.metrics`` (``repro.serving.metrics.ServingMetrics``); each
generated token is also streamed to ``Request.on_token`` the moment it is
sampled.  ``run()`` is a thin closed-loop compatibility wrapper (submit
everything at "now", drain FCFS) and is bit-identical to the historical
static-batch runner for greedy same-seed workloads.

Bucketing policy
----------------
Chunk lengths are drawn from the small static set ``prefill_chunks`` (the
pass is padded up to the smallest bucket that fits, per-slot padding is
masked via ``n_tokens``), so jit compiles at most ``len(prefill_chunks)``
prefill shapes — occupancy, chunk fill, and slot membership are all data,
not shape.

Numerics
--------
Pluggable via ``QuantConfig``: ``mode="abfp_ref"`` serves the model exactly
as the AMS device would compute it (the paper's deployment target),
``mode="float"`` is the FLOAT32 reference.  ``mode="abfp_packed"`` is the
production path: all dense weights are quantized ONCE at engine init
(int8 tile codes + bf16 scales, ``models.packing``) and every pass runs the
packed Pallas kernel — no per-token weight re-quantization, half the weight
HBM traffic.  Float-mode chunked prefill is bit-identical to the token-by-
token path; ABFP modes are statistically equivalent only (the kernel's
noise PRNG salts by grid position, and chunked grids differ from
decode-shaped grids — same noise distribution, different draws).

Sampling: ``temperature == 0`` decodes greedily (argmax); ``temperature >
0`` samples from the temperature-scaled softmax using a stream seeded by
(engine seed, request uid, token index), so draws are reproducible for a
given engine seed regardless of how requests interleave across ticks.

Sharded serving
---------------
``mesh=`` (a ``jax.sharding.Mesh`` with a 'model' axis and optional
'data'/'pod' axes) makes the whole stack mesh-aware: dense weights —
including pre-packed int8 codes + bf16 scales, which shard TOGETHER —
are placed column-parallel over 'model'
(``distributed.sharding.serving_param_spec_tree``), slot state / KV
caches shard over the data axes, and every matmul dispatches through
``kernels.ops.dense_tp`` (shard_map + all-gather, noise salts
globalized per column shard).  Column-parallel splitting never crosses
an ABFP K-tile and never reorders an f32 contraction, so greedy decode
is BIT-IDENTICAL to the single-device engine at any mesh shape, noise
included — the open-loop submit/poll/drain API is unchanged
(tests/test_sharded_serving.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.abfp import QuantConfig
from repro.distributed.fault import StragglerMonitor, plan_recovery_mesh
from repro.models import decode_step, init_decode_state, prefill
from repro.models.layers import Numerics
from repro.serving import faults as faultlib
from repro.serving.faults import FaultConfig, FaultPlan
from repro.serving.metrics import ServingMetrics
from repro.serving.scheduler import Scheduler, get_scheduler


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    arrival_time: Optional[float] = None    # engine clock; None = at submit
    priority: int = 0                       # larger = served first
    tenant: str = "default"                 # fairness domain for `priority`
    deadline: Optional[float] = None    # absolute engine-clock time; past it
                                        # the request is cancelled (queued or
                                        # in-flight) and marked timed_out
    on_token: Optional[Callable[["Request", int], None]] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_pos: int = 0                 # prompt tokens consumed so far
    done: bool = False
    timed_out: bool = False             # cancelled by deadline expiry


class ServingEngine:
    def __init__(self, params, mcfg: ModelConfig, *, capacity: int = 8,
                 max_len: int = 512,
                 quant: QuantConfig = QuantConfig(mode="float"),
                 seed: int = 0,
                 prefill_chunks: Sequence[int] = (16, 64, 128),
                 chunked: bool = True,
                 policy: Union[str, Scheduler] = "fcfs",
                 tick_time: float = 1.0,
                 clock: Optional[Callable[[], float]] = None,
                 mesh=None,
                 faults: Optional[Union[FaultConfig, FaultPlan]] = None,
                 recovery: bool = True,
                 detect_every: int = 4):
        self.mesh = mesh
        if quant.mode == "abfp_packed":
            # Quantize-once: pack every dense weight at admission time so
            # the per-tick decode path only streams int8 codes + bf16
            # scales (the paper's program-the-array-once deployment).  With
            # a mesh, codes + scales are column-sharded together over the
            # 'model' axis as part of the same one-time step.
            from repro.models.packing import pack_model_params
            params = pack_model_params(params, quant, mcfg, mesh=mesh)
        elif mesh is not None:
            from repro.distributed.sharding import shard_serving_params
            params = shard_serving_params(params, mesh, quant)
        self.params = params
        self.mcfg = mcfg
        self.capacity = capacity
        self.max_len = max_len
        self.quant = quant
        self.seed = seed
        self.key = jax.random.PRNGKey(seed)
        self.state = init_decode_state(mcfg, capacity, max_len)
        if mesh is not None:
            # Slot state / KV caches shard over the data axes (slot = batch
            # row); everything stays replicated over 'model' so the
            # column-parallel matmul dispatch keeps results bit-identical
            # to single-device at any mesh shape.
            from repro.distributed.sharding import shard_decode_state
            self.state = shard_decode_state(self.state, mesh)
        self.slots: List[Optional[Request]] = [None] * capacity
        self._next_input = np.zeros((capacity,), np.int32)
        self.ticks = 0
        self.prefill_chunks = tuple(sorted({int(c) for c in prefill_chunks}))
        self.chunked = chunked and bool(self.prefill_chunks)
        self.scheduler = get_scheduler(policy)
        self.metrics = ServingMetrics(capacity)
        self.tick_time = float(tick_time)
        self._clock = clock             # None => simulated (tick_time/pass)
        self.now = clock() if clock is not None else 0.0
        self._just_finished: List[Request] = []
        self._has_deadlines = False     # set on first deadline'd request

        # Wall-clock tick monitoring: every jitted pass's host-visible
        # duration feeds the trailing-median straggler model; escalation
        # state (log -> reslice -> remesh) surfaces in metrics.summary().
        self.straggler = StragglerMonitor()
        self.metrics.straggler = self.straggler

        # -- fault tolerance (serving.faults) ------------------------------
        # With ``faults=None`` nothing below exists on the hot path: the
        # params, jitted functions, and per-tick flow are identical to a
        # build without fault machinery (zero-overhead guarantee,
        # parity-tested in tests/test_faults.py).
        self.recovery = recovery
        self.detect_every = max(1, int(detect_every))
        self._fault_cursor = 0
        self._lost_shard: Optional[int] = None
        self._fault_dirty = False       # unrepaired injected faults active
        if isinstance(faults, FaultConfig):
            from repro.kernels.ops import tp_size
            faults = faultlib.make_fault_plan(self.params, faults,
                                              tp=tp_size(mesh))
        self.fault_plan: Optional[FaultPlan] = faults
        if self.fault_plan is not None:
            # Clean copy = the replicated hot spare the repairs re-program
            # from (a reference, not a copy: injection replaces arrays).
            self._params_clean = self.params
            self._fault_sites = faultlib.fault_sites(self.params)
            self._baselines = faultlib.fingerprint_baselines(self.params)

        self._build_jitted()

    def _build_jitted(self):
        """(Re)build the jitted step/prefill/reset closures for the current
        mesh — called at init and again after a shard-drop re-shard."""
        mcfg, quant, mesh = self.mcfg, self.quant, self.mesh

        def _step(params, state, token, key):
            nx = Numerics(quant, key, mesh=mesh)
            return decode_step(params, state, token, mcfg, nx)

        self._jit_step = jax.jit(_step, donate_argnums=(1,))

        def _prefill(params, state, tokens, n_tokens, key):
            nx = Numerics(quant, key, mesh=mesh)
            return prefill(params, state, tokens, n_tokens, mcfg, nx)

        # One compile per chunk bucket (shape-specialized), nothing more.
        self._jit_prefill = jax.jit(_prefill, donate_argnums=(1,))

        def _reset(state, i):
            def reset(path, leaf):
                names = [str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path]
                b_axis = 1 if "groups" in names else 0
                if leaf.ndim <= b_axis:
                    return leaf
                idx = (slice(None),) * b_axis + (i,)
                fill = (-1e30 if names[-1] == "m" and leaf.ndim - b_axis == 3
                        else 0)
                return leaf.at[idx].set(jnp.asarray(fill, leaf.dtype))

            return jax.tree_util.tree_map_with_path(reset, state)

        # Compile-once slot reset: the slot index is data, so admission
        # under churn costs one fused scatter pass instead of a host-side
        # state rebuild that scales with model size.
        self._jit_reset = jax.jit(_reset, donate_argnums=(0,))

    # -- clock ----------------------------------------------------------------
    def _tick_clock(self):
        """One jitted pass just ran: advance the engine clock (simulated
        ticks or wall time) BEFORE tokens from that pass are recorded."""
        self.ticks += 1
        self.now = (self._clock() if self._clock is not None
                    else self.now + self.tick_time)

    # -- slot state reset -------------------------------------------------
    def _reset_slot(self, i: int):
        self.state = self._jit_reset(self.state, jnp.int32(i))

    # -- admission ------------------------------------------------------------
    def fits(self, req: Request) -> bool:
        """A request needs a non-empty prompt (there is no token to condition
        the first generation on otherwise) and must leave room for at least
        one generated token — the chunk scatter parks padding lanes on the
        next unwritten cache slot, which only exists while
        length + n_tokens < max_len."""
        return (len(req.prompt) >= 1
                and len(req.prompt) + max(1, req.max_new_tokens)
                <= self.max_len)

    def submit(self, req: Request) -> bool:
        """Enqueue a request for arrival-driven admission.  Stamps
        ``arrival_time`` with the current clock when unset.  Oversized
        requests are rejected (marked done, recorded in metrics) instead of
        crashing the serve loop; returns False for those."""
        if not self.fits(req):
            req.done = True
            self.metrics.on_reject(req.uid)
            return False
        if req.arrival_time is None:
            req.arrival_time = self.now
        if req.deadline is not None:
            self._has_deadlines = True
        self.metrics.on_submit(req.uid, arrival_time=req.arrival_time,
                               tenant=req.tenant,
                               prompt_len=len(req.prompt))
        self.scheduler.add(req)
        return True

    def try_admit(self, req: Request) -> bool:
        if not self.fits(req):
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) must be "
                f"non-empty and prompt + max_new ({req.max_new_tokens}) "
                f"must fit max_len ({self.max_len})")
        for i, slot in enumerate(self.slots):
            if slot is None:
                self._reset_slot(i)
                self.slots[i] = req
                if req.arrival_time is None:
                    req.arrival_time = self.now
                if req.deadline is not None:
                    self._has_deadlines = True
                self.metrics.on_admit(req.uid, self.now, tenant=req.tenant,
                                      prompt_len=len(req.prompt),
                                      arrival_time=req.arrival_time)
                if self.chunked:
                    req.prompt_pos = 0      # consumed by prefill passes
                else:
                    # Legacy prefill-in-decode: one prompt token per tick.
                    self._next_input[i] = req.prompt[0]
                    req.prompt_pos = 1
                return True
        return False

    def _admit_arrived(self) -> List[Request]:
        """Fill free slots from the scheduler queue (policy order) with
        requests that have arrived by the current clock."""
        admitted: List[Request] = []
        free = self.slots.count(None)
        while free > 0:
            req = self.scheduler.pop(self.now)
            if req is None:
                break
            self.try_admit(req)     # a slot is free; fits() held at submit
            admitted.append(req)
            free -= 1
        return admitted

    # -- sampling -------------------------------------------------------------
    def _record(self, i: int, req: Request, logits_row: np.ndarray):
        if req.temperature > 0:
            # Temperature sampling from the engine's seeded stream: the
            # draw is keyed by (engine seed, uid, token index), so outputs
            # are reproducible for a given engine seed no matter how the
            # scheduler interleaves this request with others.
            z = logits_row.astype(np.float64) / req.temperature
            z -= z.max()
            p = np.exp(z)
            p /= p.sum()
            rng = np.random.default_rng(
                (self.seed, req.uid, len(req.generated)))
            nxt = int(rng.choice(len(p), p=p))
        else:
            nxt = int(np.argmax(logits_row))
        req.generated.append(nxt)
        self._next_input[i] = nxt
        self.metrics.on_token(req.uid, self.now)
        if self._fault_dirty:
            # This token was computed against faulted weights that no
            # detection round has repaired yet: the request's output can't
            # be trusted.  (Cleared if recovery later requeues it.)
            self.metrics.on_corrupted(req.uid)
        if req.on_token is not None:
            req.on_token(req, nxt)
        if len(req.generated) >= req.max_new_tokens:
            req.done = True
            self.slots[i] = None            # free for the next request
            self.metrics.on_finish(req.uid, self.now)
            self._just_finished.append(req)

    # -- deadlines --------------------------------------------------------
    def _expire_slots(self):
        """Cancel in-flight requests past their deadline: free the slot
        immediately (the next admit resets its state) instead of letting a
        stuck request squat until max_new_tokens."""
        for i, req in enumerate(self.slots):
            if (req is not None and req.deadline is not None
                    and req.deadline <= self.now):
                self.slots[i] = None
                req.done = True
                req.timed_out = True
                self.metrics.on_timeout(req.uid, self.now)
                self._just_finished.append(req)

    def _expire_queue(self) -> List[Request]:
        """Time out queued requests whose deadline already passed."""
        expired = self.scheduler.expire(self.now)
        for req in expired:
            req.done = True
            req.timed_out = True
            self.metrics.on_timeout(req.uid, self.now)
        return expired

    # -- fault tolerance --------------------------------------------------
    def _inject_due_faults(self):
        """Apply every fault event scheduled at or before the current tick:
        a sharding-preserving rewrite of the packed operands the jitted
        step streams (serving.faults), so the fault flows through
        dense_tp / the packed kernels at any mesh shape."""
        from repro.kernels.ops import tp_size
        due, self._fault_cursor = self.fault_plan.due(
            self.ticks, self._fault_cursor)
        for ev in due:
            if ev.kind == "shard_drop":
                # The injectable host-failure signal distributed.fault
                # documents — recovery reads it as a health-check verdict.
                self._lost_shard = ev.shard
            self.params = faultlib.apply_event(
                self.params, ev, tp=tp_size(self.mesh), quant=self.quant,
                mesh=self.mesh)
            self.metrics.on_fault(ev.kind)
            self._fault_dirty = True

    def _detect_and_recover(self):
        """One detection round: fingerprint-probe every fault site against
        its healthy baseline; with recovery on, repair what was found
        (re-quantize drifted tiles, remap stuck columns, re-shard on a
        lost-shard health signal + requeue its in-flight requests)."""
        if self._lost_shard is not None and self.recovery:
            self._reshard_and_requeue()
            return
        hits = []
        for site in self._fault_sites:
            cur = faultlib.site_fingerprint(self.params, site)
            det = faultlib.detect_site(self._baselines[site.path], cur)
            if not det.clean:
                hits.append((site, det))
        if hits:
            self.metrics.on_detected(sum(
                len(d.stuck_cols) + len(d.drifted) for _, d in hits))
        if not self.recovery:
            return
        for site, det in hits:
            if det.stuck_cols:
                self.params = faultlib.repair_stuck(
                    self.params, self._params_clean, site.path,
                    det.stuck_cols)
                self.metrics.on_repair("cols_remapped", len(det.stuck_cols))
            if det.drifted:
                self.params = faultlib.repair_drift(
                    self.params, self._params_clean, site.path, det.drifted)
                self.metrics.on_repair("tiles_requantized", len(det.drifted))
        if hits:
            # Tokens emitted during the dirty window were computed against
            # faulted weights; with recovery on they are DISCARDED and the
            # request re-decoded from the now-clean array (a shipped token
            # is gone, so only in-flight requests can be salvaged).
            self._requeue_corrupted()
        # Everything detectable was just repaired; ticks from here on are
        # clean until the next injection flips the flag back.
        self._fault_dirty = False

    def _requeue_corrupted(self):
        """Restart in-flight requests whose partial output (and KV cache)
        was produced under an active fault: free the slot, clear generated
        tokens, and requeue — arrival order is preserved, so they re-admit
        ahead of younger traffic."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            rec = self.metrics.requests.get(req.uid)
            if rec is None or not rec.corrupted:
                continue
            self.slots[i] = None
            self._next_input[i] = 0
            req.prompt_pos = 0
            req.generated.clear()
            self.metrics.on_requeue(req.uid)
            self.scheduler.requeue(req)

    def _reshard_and_requeue(self):
        """Shard-drop recovery: re-plan the mesh without the lost bank
        (distributed.fault.plan_recovery_mesh), re-program weights from
        the clean master onto the surviving chips, and requeue every
        in-flight request through the scheduler with state reset — the
        lost shard's slot state (KV caches) died with it, but no request
        is ever lost (conservation: submitted == completed + rejected +
        timed_out still holds over the whole trace)."""
        import numpy as onp
        from jax.sharding import Mesh

        from repro.distributed.sharding import (
            shard_decode_state,
            shard_serving_params,
        )

        self._lost_shard = None
        if self.mesh is not None and self.mesh.devices.size > 1:
            old_shape = tuple(self.mesh.devices.shape)
            dp, tp = old_shape
            # Losing model bank s costs its chip in every data row.
            plan = plan_recovery_mesh(dp * tp - dp, tp, old_shape)
            devices = list(self.mesh.devices.flat)
            keep = devices[: plan.new_shape[0] * plan.new_shape[1]]
            self.mesh = Mesh(
                onp.asarray(keep).reshape(plan.new_shape),
                self.mesh.axis_names)
            self.params = shard_serving_params(
                self._params_clean, self.mesh, self.quant)
            self._params_clean = self.params
            self._build_jitted()        # closures bind the new mesh
            self.state = init_decode_state(self.mcfg, self.capacity,
                                           self.max_len)
            self.state = shard_decode_state(self.state, self.mesh)
        else:
            # Single-array engine: re-program the array from the spare.
            self.params = self._params_clean
            self.state = init_decode_state(self.mcfg, self.capacity,
                                           self.max_len)
        inflight = [r for r in self.slots if r is not None]
        self.slots = [None] * self.capacity
        self._next_input[:] = 0
        for req in inflight:
            req.prompt_pos = 0
            req.generated.clear()
            self.metrics.on_requeue(req.uid)
            self.scheduler.requeue(req)
        self.metrics.on_repair("reshards", 1)
        self._fault_dirty = False

    # -- one engine tick ------------------------------------------------------
    def step(self):
        # Completion flushing happens per pass (not only per poll) so a
        # long-lived engine driven through the legacy try_admit()/step()
        # path never accumulates finished Request objects.
        self._just_finished = []
        if self._has_deadlines:
            self._expire_slots()
            self._just_finished.extend(self._expire_queue())
        if self.fault_plan is not None:
            # Detect (and repair) faults from earlier ticks BEFORE this
            # tick's injections land, so every fault is live for at least
            # one pass — then inject whatever the plan schedules now.
            if self.ticks % self.detect_every == 0 and (
                    self._fault_dirty or self._lost_shard is not None):
                self._detect_and_recover()
            self._inject_due_faults()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return
        self.metrics.on_tick(self.now, len(live), self.capacity,
                             self.scheduler.pending(self.now))
        prefilling = [i for i in live
                      if self.slots[i].prompt_pos < len(self.slots[i].prompt)]
        if self.chunked and prefilling:
            if all(len(self.slots[i].prompt) - self.slots[i].prompt_pos == 1
                   for i in prefilling):
                # Every prefilling slot has exactly ONE prompt token left:
                # the decode tick already has the right shape, so feed that
                # token as the decode input instead of paying a padded
                # smallest-bucket chunk pass.
                for i in prefilling:
                    req = self.slots[i]
                    self._next_input[i] = req.prompt[req.prompt_pos]
                    req.prompt_pos += 1
                self._decode_tick()
            else:
                self._prefill_pass(live)
        else:
            self._decode_tick()

    def _prefill_pass(self, live: List[int]):
        """One bucketed prefill pass: prompt chunks for prefilling slots,
        a single next token for decoding slots, no-op for empty slots."""
        need = np.zeros((self.capacity,), np.int32)
        for i in live:
            req = self.slots[i]
            rem = len(req.prompt) - req.prompt_pos
            need[i] = min(rem, self.prefill_chunks[-1]) if rem > 0 else 1
        bucket = next(c for c in self.prefill_chunks if c >= need.max())

        tokens = np.zeros((self.capacity, bucket), np.int32)
        for i in live:
            req = self.slots[i]
            if req.prompt_pos < len(req.prompt):
                n = int(need[i])
                tokens[i, :n] = req.prompt[req.prompt_pos:req.prompt_pos + n]
            else:
                tokens[i, 0] = self._next_input[i]
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        logits, self.state = self._jit_prefill(
            self.params, self.state, jnp.asarray(tokens),
            jnp.asarray(need), sub)
        logits = np.asarray(logits, np.float32)     # host sync point
        self.straggler.observe(time.perf_counter() - t0)
        self._tick_clock()

        for i in live:
            req = self.slots[i]
            if req.prompt_pos < len(req.prompt):
                req.prompt_pos += int(need[i])
                if req.prompt_pos < len(req.prompt):
                    continue                # still prefilling; logits unused
            # Prompt just completed (logits are at its last prompt token) or
            # the slot was decoding: sample the next token either way.
            self._record(i, req, logits[i])

    def _decode_tick(self):
        token = jnp.asarray(self._next_input)
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        logits, self.state = self._jit_step(self.params, self.state, token, sub)
        logits = np.asarray(logits, np.float32)     # host sync point
        self.straggler.observe(time.perf_counter() - t0)
        self._tick_clock()

        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.prompt_pos < len(req.prompt):
                # legacy prefill-in-decode: feed the next prompt token
                self._next_input[i] = req.prompt[req.prompt_pos]
                req.prompt_pos += 1
                continue
            self._record(i, req, logits[i])

    # -- open-loop API ----------------------------------------------------
    def poll(self) -> List[Request]:
        """One arrival-driven engine round: sync the clock, admit every
        arrived request the policy picks, run one ``step()``.  Returns the
        requests that FINISHED during this poll (possibly empty).  With the
        simulated clock an idle engine jumps straight to the next arrival;
        with a real clock it returns immediately and the caller re-polls."""
        if self._clock is not None:
            self.now = self._clock()
        self._admit_arrived()
        if all(s is None for s in self.slots):
            nxt = self.scheduler.next_arrival()
            if nxt is None:
                return []                   # fully drained
            if self._clock is not None:
                # Real time hasn't caught up to the next arrival: nap
                # (capped) instead of letting drain() busy-spin a core
                # through the inter-arrival gap.
                if nxt > self.now:
                    time.sleep(min(nxt - self.now, 0.01))
                return []
            self.now = max(self.now, nxt)
            self._admit_arrived()
        self.step()
        return list(self._just_finished)

    def drain(self) -> List[Request]:
        """Poll until the queue and every slot are empty; returns finished
        requests in completion order."""
        finished: List[Request] = []
        while (len(self.scheduler)
               or any(s is not None for s in self.slots)):
            finished.extend(self.poll())
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        """Closed-loop compatibility wrapper: serve a static workload to
        completion under the engine's policy (FCFS by default, matching the
        historical behavior bit-for-bit for greedy same-seed workloads).
        Oversized requests are rejected up front (marked done, nothing
        generated) rather than crashing the serve loop mid-flight."""
        finished: List[Request] = []
        for r in requests:
            if not self.submit(r):
                finished.append(r)
        finished.extend(self.drain())
        return finished
