"""DeviceStream: the seam isolating host<->device sync points.

The serving engine never calls ``np.asarray`` on a device array directly;
every host-visible transfer goes through its stream, which comes in two
flavors:

* :class:`DeviceStream` — the BLOCKING policy (and the default).  ``fetch``
  is an immediate host sync (counted in ``host_syncs`` so tests can assert
  a pass performed no transfer), ``submit`` delivers a ticket inline, and
  ``sync`` is a no-op because nothing is ever in flight.  The simulated
  clock path runs on this stream, bit-identical to the pre-stream engine.

* :class:`OverlappedStream` — the wall-clock overlapped policy.  ``submit``
  enqueues a delivery ticket on a BOUNDED queue consumed by one background
  worker thread; the bound is the dispatch-ahead depth, so a host that
  outruns delivery blocks on ``submit`` instead of growing an unbounded
  backlog of undelivered tokens.  The worker resolves each ticket's device
  arrays (jax async dispatch means that resolution is the only wait),
  fires streaming callbacks, and finalizes metrics — while the engine's
  main thread is already dispatching the next pass.  ``sync`` drains the
  queue (the engine calls it before anything that must see complete token
  streams: preemption replay snapshots, deadline expiry, fault requeues).

Worker exceptions are captured and re-raised on the next ``submit``/
``sync`` so a failing callback surfaces in the serve loop instead of dying
silently on a daemon thread.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, List, Optional

import numpy as np


@dataclasses.dataclass
class TokenRec:
    """One slot that sampled a token in a dispatched pass."""
    slot: int
    req: Any                    # serving.engine.Request
    finishing: bool             # this token hits the request's limit
    corrupted: bool             # dispatched while an unrepaired fault was live


@dataclasses.dataclass
class Ticket:
    """One dispatched pass awaiting delivery: the (unfetched) device array
    of sampled tokens plus everything delivery needs — recipients, the
    dispatch timestamp for the straggler/utilization gauges, the engine
    clock reading the tokens are stamped with, and the warmup flag that
    keeps first-execution-per-shape samples out of the straggler model."""
    engine: Any                 # serving.engine.ServingEngine
    t0: float                   # host perf-clock at dispatch
    warmup: bool                # first run of this executable shape
    sampled: Any                # (B,) int32 device array
    recs: List[TokenRec]
    now: float                  # engine clock at dispatch (token timestamps)


class DeviceStream:
    """Blocking sync policy: transfers happen inline, nothing is ever
    pending.  Also the instrumentation point — ``host_syncs`` counts every
    device->host transfer the engine performed."""

    def __init__(self) -> None:
        self.host_syncs = 0

    def fetch(self, arr, dtype=None) -> np.ndarray:
        """Device -> host transfer (THE sync point)."""
        self.host_syncs += 1
        return np.asarray(arr) if dtype is None else np.asarray(arr, dtype)

    def submit(self, ticket: Ticket) -> None:
        ticket.engine._deliver_ticket(ticket)

    def pending(self) -> int:
        return 0

    def sync(self) -> None:
        pass

    def close(self) -> None:
        pass


class OverlappedStream(DeviceStream):
    """Background delivery over a bounded queue (see module docstring).

    ``depth`` bounds how many dispatched-but-undelivered passes may exist;
    the engine's dispatch loop blocks on ``submit`` past it.
    """

    def __init__(self, depth: int = 4) -> None:
        super().__init__()
        self._q: "queue.Queue[Optional[Ticket]]" = queue.Queue(
            maxsize=max(1, int(depth)))
        self._exc: Optional[BaseException] = None
        self._closed = False
        self._worker = threading.Thread(
            target=self._run, name="serving-delivery", daemon=True)
        self._worker.start()

    def _run(self) -> None:
        while True:
            ticket = self._q.get()
            if ticket is None:
                self._q.task_done()
                return
            try:
                ticket.engine._deliver_ticket(ticket)
            except BaseException as e:     # surface on the engine thread
                self._exc = e
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def submit(self, ticket: Ticket) -> None:
        self._raise_pending()
        if self._closed:
            raise RuntimeError("OverlappedStream is closed")
        self._q.put(ticket)

    def pending(self) -> int:
        return int(self._q.unfinished_tasks)

    def sync(self) -> None:
        """Block until every submitted ticket has been delivered."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._worker.join(timeout=10.0)
