"""ModelRunner: the seam between ``ServingEngine`` and ``repro.models``.

The engine used to hardcode decoder-only semantics — token-in/logits-out
step, KV-strip/paged state reset-attach-copy via path-name matching, and
``fits()`` measured in KV tokens.  A ``ModelRunner`` owns everything the
engine needs to know about one architecture family:

  * ``init_state``      — allocate the batched decode state
  * ``make_step`` / ``make_prefill`` — build the pure functions the engine
    jits (decode tick / bucketed chunk pass), closure-identical to the
    pre-runner engine so greedy decode stays bit-identical
  * ``make_reset`` / ``make_attach`` / ``make_copy_page`` — the compile-once
    slot-state scatter passes (admission reset, prefix-cache attach, CoW
    page duplication)
  * ``make_admit``      — optional per-slot admission pass (EncDec: one
    encoder forward cached as cross-attention KV)
  * ``state_spec`` / ``shard_state`` — mesh placement of the decode state
  * ``capacity_cost``   — pages a request of N total tokens will occupy
    (attention KV) or 0 (recurrent state is O(1) per slot)

Three implementations cover the zoo (see ``runner_for``):

  * ``DecoderRunner``   — decoder-only full-attention LMs (KV caches grow
    per token; paged pool eligible).
  * ``RecurrentRunner`` — ssm / hybrid archs (xlstm, recurrentgemma):
    decode state is FIXED-SIZE (recurrent folds + ring-buffer window
    caches), so requests bypass page accounting entirely and are never
    preempted by pool pressure.
  * ``EncDecRunner``    — whisper-style encoder-decoder: one encoder pass
    at admission, cached per slot as cross-attention K/V in the decode
    state; decode then proceeds like a decoder-only model (the
    self-attention KV still pages normally).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import (
    decode_step,
    encode,
    encode_cross_kv,
    init_decode_state,
    prefill,
    sample_tokens,
)
from repro.models.layers import Numerics
from repro.serving.pages import pages_needed


def _names(path):
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


def _batch_axis(names) -> int:
    """Leaves stacked over scan groups — and the EncDec per-slot encoder
    cache, which carries a leading (n_groups,) axis too — hold the slot
    batch at axis 1; everything else at axis 0."""
    return 1 if ("groups" in names or "enc" in names) else 0


class ModelRunner:
    """Decoder-only behavior; the base class IS ``DecoderRunner``'s
    implementation and the other runners override only what differs."""

    #: May this model's KV state live in the shared page pool?
    paged_ok: bool = False
    #: O(1) decode state per slot (bypasses max_len and page accounting)?
    fixed_state: bool = False
    #: Does admission need a jitted per-slot pass (``make_admit``)?
    needs_admission: bool = False
    #: Is cross-request prefix-page sharing sound for this model?  (False
    #: when decoder state depends on per-request side inputs — EncDec.)
    prefix_cache_ok: bool = True

    def __init__(self, mcfg: ModelConfig):
        self.mcfg = mcfg

    # -- state ------------------------------------------------------------
    def init_state(self, capacity: int, max_len: int, *,
                   page_size: Optional[int] = None,
                   pool_pages: Optional[int] = None) -> dict:
        return init_decode_state(self.mcfg, capacity, max_len,
                                 page_size=page_size, pool_pages=pool_pages)

    def state_spec(self, state, mesh):
        from repro.distributed.sharding import serving_state_spec_tree
        return serving_state_spec_tree(state, mesh)

    def shard_state(self, state, mesh):
        from repro.distributed.sharding import shard_decode_state
        return shard_decode_state(state, mesh)

    # -- capacity ---------------------------------------------------------
    def capacity_cost(self, total_tokens: int, page_size: int) -> int:
        """Pages a request of ``total_tokens`` (prompt + max_new) occupies
        at full length.  Attention KV grows per token; recurrent state
        overrides this to 0."""
        return pages_needed(total_tokens, page_size)

    def accepts(self, req) -> bool:
        """Model-specific request validation beyond the engine's generic
        ``fits()`` (prompt shape, side inputs...)."""
        return True

    # -- jit-ready closures (the engine jits these verbatim) ---------------
    @staticmethod
    def _replicated(x, mesh):
        """Pin a sampled-token array to a canonical replicated sharding so
        the warmed executables accept it back as the next pass's input
        (the engine feeds device samples straight into the next dispatch
        without ever fetching them)."""
        if mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec()))

    def _step_core(self, params, state, token, key, quant, mesh):
        """The model-family decode-tick body shared by BOTH closure forms
        below (legacy logits-out and sampled); overriding this is how a
        family changes its step without touching sampling."""
        nx = Numerics(quant, key, mesh=mesh)
        return decode_step(params, state, token, self.mcfg, nx)

    def _prefill_core(self, params, state, tokens, n_tokens, key, quant,
                      mesh):
        nx = Numerics(quant, key, mesh=mesh)
        return prefill(params, state, tokens, n_tokens, self.mcfg, nx)

    def make_step(self, quant, mesh, seed=None):
        """Build the jit-ready decode-tick closure.

        ``quant`` selects the whole numerics stack inside the closure via
        ``Numerics``: with ``mode="abfp_fused"`` (and the weights packed
        with per-tile gains at engine init) every decode tick's attention
        block routes through the fused QKV + quantized-attention kernels
        of ``kernels.abfp_decode_fused``; the closure itself is identical
        across modes, so the engine jits exactly one step function either
        way.

        With ``seed=None`` (the legacy form external callers use) the
        closure is ``(params, state, token, key) -> (logits, new_state)``.
        With an integer seed the engine gets the SAMPLED form the serving
        tick runs: ``(params, state, token, ov_vals, ov_mask, key, temps,
        uids, idxs) -> (logits, sampled, new_state)`` — the next token is
        drawn on device (``models.sample_tokens``) so the overlapped
        runtime never syncs logits to the host, and ``ov_mask`` lets the
        host override per-slot inputs (prompt feeds) while every other
        slot consumes the previous pass's device sample.  Both forms wrap
        the same ``_step_core`` body, so the logits math is identical.
        """
        if seed is None:
            def _step(params, state, token, key):
                return self._step_core(params, state, token, key, quant,
                                       mesh)

            return _step

        def _step(params, state, token, ov_vals, ov_mask, key, temps, uids,
                  idxs):
            tok = jnp.where(ov_mask, ov_vals, token)
            logits, new_state = self._step_core(params, state, tok, key,
                                                quant, mesh)
            nxt = self._replicated(
                sample_tokens(logits, temps, uids, idxs, seed), mesh)
            return logits, nxt, new_state

        return _step

    def make_prefill(self, quant, mesh, seed=None):
        """Legacy form (``seed=None``): ``(params, state, tokens, n_tokens,
        key) -> (logits, new_state)``.  Sampled form: adds ``riders`` /
        ``rider_mask`` — decode slots riding along in a chunk pass take
        their single input token from the previous pass's on-device sample
        instead of a host value — and returns ``(logits, sampled,
        new_state)`` like the sampled step."""
        if seed is None:
            def _prefill(params, state, tokens, n_tokens, key):
                return self._prefill_core(params, state, tokens, n_tokens,
                                          key, quant, mesh)

            return _prefill

        def _prefill(params, state, tokens, n_tokens, riders, rider_mask,
                     key, temps, uids, idxs):
            first = jnp.where(rider_mask, riders, tokens[:, 0])
            toks = tokens.at[:, 0].set(first)
            logits, new_state = self._prefill_core(
                params, state, toks, n_tokens, key, quant, mesh)
            nxt = self._replicated(
                sample_tokens(logits, temps, uids, idxs, seed), mesh)
            return logits, nxt, new_state

        return _prefill

    def make_admit(self, quant, mesh):
        raise NotImplementedError(
            f"{type(self).__name__} has no admission pass")

    def make_reset(self):
        def _reset(state, i):
            def reset(path, leaf):
                names = _names(path)
                if names[-1].endswith("_pages") or names[-1] == "page_table":
                    # Pool pages are GLOBAL (other slots own them); the
                    # page table is host-owned and refreshed every pass.
                    return leaf
                b_axis = _batch_axis(names)
                if leaf.ndim <= b_axis:
                    return leaf
                idx = (slice(None),) * b_axis + (i,)
                fill = (-1e30 if names[-1] == "m" and leaf.ndim - b_axis == 3
                        else 0)
                return leaf.at[idx].set(jnp.asarray(fill, leaf.dtype))

            return jax.tree_util.tree_map_with_path(reset, state)

        return _reset

    def make_attach(self):
        def _attach(state, i, length):
            # Prefix-cache attach: slot i starts mid-sequence — its cache
            # length and rope position jump to the shared-prefix length.
            def setl(path, leaf):
                names = _names(path)
                if names[-1] not in ("position", "length"):
                    return leaf
                b_axis = _batch_axis(names)
                idx = (slice(None),) * b_axis + (i,)
                return leaf.at[idx].set(jnp.asarray(length, leaf.dtype))

            return jax.tree_util.tree_map_with_path(setl, state)

        return _attach

    def make_copy_page(self):
        def _copy_page(state, src, dst):
            # Copy-on-write: duplicate one physical page across every
            # layer's pool (src/dst are data, so one compile serves all
            # CoW splits).
            def cp(path, leaf):
                names = _names(path)
                if not names[-1].endswith("_pages"):
                    return leaf
                if "groups" in names:
                    return leaf.at[:, dst].set(leaf[:, src])
                return leaf.at[dst].set(leaf[src])

            return jax.tree_util.tree_map_with_path(cp, state)

        return _copy_page


class DecoderRunner(ModelRunner):
    """Decoder-only (and any full-attention) LM: KV caches grow per token
    and may live in the shared page pool."""

    fixed_state = False
    needs_admission = False

    @property
    def paged_ok(self) -> bool:
        return self.mcfg.attention_type == "full"


class RecurrentRunner(ModelRunner):
    """ssm / hybrid archs (xlstm, recurrentgemma): recurrent folds and
    ring-buffer window caches are FIXED-SIZE per slot, so requests bypass
    page accounting (``capacity_cost == 0``), are admissible at any total
    length, and can never be preempted by pool pressure (their lane runs
    unpaged — ``paged_ok`` is False)."""

    paged_ok = False
    fixed_state = True

    def capacity_cost(self, total_tokens: int, page_size: int) -> int:
        return 0


class EncDecRunner(ModelRunner):
    """Whisper-style encoder-decoder.  Admission runs ONE jitted encoder
    pass over the request's frontend features and scatters the resulting
    cross-attention K/V into the slot's ``state["enc"]`` cache; decode then
    proceeds exactly like a decoder-only model, with the cached enc K/V
    threaded into every pass.  The decoder's own self-attention KV still
    pages normally (whisper is full-attention), but prefix-page sharing is
    DISABLED: decoder KV depends on the per-request encoder output, so two
    requests with equal prompts but different audio must not share pages.

    ``enc_len`` is the fixed encoder frame count (one jit compile); a
    request must carry ``features`` of shape (enc_len, d_model)."""

    needs_admission = True
    prefix_cache_ok = False

    DEFAULT_ENC_LEN = 64

    def __init__(self, mcfg: ModelConfig, enc_len: int = DEFAULT_ENC_LEN):
        assert mcfg.is_encoder_decoder, mcfg.name
        super().__init__(mcfg)
        self.enc_len = int(enc_len)

    @property
    def paged_ok(self) -> bool:
        return self.mcfg.attention_type == "full"

    def accepts(self, req) -> bool:
        feats = getattr(req, "features", None)
        if feats is None:
            return False
        shape = tuple(getattr(feats, "shape", ()))
        return shape == (self.enc_len, self.mcfg.d_model)

    def init_state(self, capacity: int, max_len: int, *,
                   page_size: Optional[int] = None,
                   pool_pages: Optional[int] = None) -> dict:
        state = super().init_state(capacity, max_len, page_size=page_size,
                                   pool_pages=pool_pages)
        mcfg = self.mcfg
        pattern = mcfg.block_pattern or ("attention",)
        n_groups = mcfg.num_layers // len(pattern)
        kh, hd = mcfg.num_kv_heads, mcfg.resolved_head_dim
        # Per-slot encoder K/V, one entry per pattern position, stacked
        # over scan groups like params["groups"] — consumed by decode_step
        # / prefill via their ``enc_kv`` scan input.
        state["enc"] = tuple(
            {"k": jnp.zeros((n_groups, capacity, self.enc_len, kh, hd),
                            mcfg.activation_dtype),
             "v": jnp.zeros((n_groups, capacity, self.enc_len, kh, hd),
                            mcfg.activation_dtype)}
            for _ in pattern)
        return state

    @staticmethod
    def _split_enc(state):
        enc = state["enc"]
        rest = {k: v for k, v in state.items() if k != "enc"}
        enc_kv = [(e["k"], e["v"]) for e in enc]
        return rest, enc, enc_kv

    def _step_core(self, params, state, token, key, quant, mesh):
        rest, enc, enc_kv = self._split_enc(state)
        nx = Numerics(quant, key, mesh=mesh)
        logits, new_state = decode_step(params, rest, token, self.mcfg, nx,
                                        enc_kv=enc_kv)
        new_state["enc"] = enc
        return logits, new_state

    def _prefill_core(self, params, state, tokens, n_tokens, key, quant,
                      mesh):
        rest, enc, enc_kv = self._split_enc(state)
        nx = Numerics(quant, key, mesh=mesh)
        logits, new_state = prefill(params, rest, tokens, n_tokens,
                                    self.mcfg, nx, enc_kv=enc_kv)
        new_state["enc"] = enc
        return logits, new_state

    def make_admit(self, quant, mesh):
        """One encoder pass for slot ``i``: features (enc_len, d_model) ->
        cross-attention K/V scattered into ``state["enc"]`` at batch row i.
        Slot index and features are data — one compile serves every
        admission."""
        mcfg = self.mcfg

        def _admit(params, state, features, i, key):
            nx = Numerics(quant, key, mesh=mesh)
            enc_out = encode(params, features[None], mcfg, nx)   # (1, S, d)
            kv = encode_cross_kv(params, enc_out, mcfg, nx)
            new_enc = []
            for j, (k, v) in enumerate(kv):
                e = state["enc"][j]
                new_enc.append({
                    "k": e["k"].at[:, i].set(k[:, 0].astype(e["k"].dtype)),
                    "v": e["v"].at[:, i].set(v[:, 0].astype(e["v"].dtype)),
                })
            out = dict(state)
            out["enc"] = tuple(new_enc)
            return out

        return _admit


def runner_for(mcfg: ModelConfig, **kwargs) -> ModelRunner:
    """Default runner for a config: EncDec for encoder-decoder models,
    Recurrent when the block pattern carries any non-attention kind
    (``attention_type`` hybrid/recurrent — fixed-size decode state), else
    plain Decoder."""
    if mcfg.is_encoder_decoder:
        return EncDecRunner(mcfg, **kwargs)
    if mcfg.attention_type in ("hybrid", "recurrent"):
        return RecurrentRunner(mcfg, **kwargs)
    return DecoderRunner(mcfg, **kwargs)
