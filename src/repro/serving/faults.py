"""Fault injection, detection, and repair for the analog serving stack.

The paper's premise is that analog hardware drifts and breaks; Demirkiran
et al. ("A Blueprint for Precise and Fault-Tolerant Analog Neural
Networks", PAPERS.md) observe that analog faults are STRUCTURED — a dead
column driver kills one output column, conductance drift scales one tile's
effective weights — and structured faults are detectable and recoverable.
This module provides the serving-side machinery:

Fault model (``FaultKind``)
---------------------------
  * ``stuck_col``   — stuck-at-zero output columns: the column's codes AND
    scales are zeroed (a dead column driver contributes nothing).
  * ``scale_drift`` — per-(tile, col) multiplicative drift on
    ``PackedWeight`` scales (conductance drift re-scales a programmed
    tile); drift factors are drawn outside the bf16 scale-storage
    tolerance so they are detectable in principle.
  * ``shard_drop``  — a whole model-axis shard dies: every column-sharded
    weight loses its columns on that shard (replicated weights survive on
    the remaining chips).  The event also raises the injectable
    host-failure signal ``distributed.fault`` documents — a real
    deployment wires GCS health checks into the same hook.

Injection is WEIGHT-SPACE: a fault event rewrites the packed operands
(int8 codes / bf16 scales — or float weight columns) that the engine's
jitted step streams, exactly as a drifted or dead analog array would
present them.  The rewrite is a sharding-preserving elementwise/scatter
update, so injected faults flow through ``kernels.ops.dense_tp`` and the
packed Pallas kernels unchanged at any (dp, tp) mesh shape — no kernel or
model code knows faults exist, and with no plan attached the engine is
bit-identical to a fault-free build (zero-overhead guarantee).

Plans are DETERMINISTIC: ``make_fault_plan(params, cfg)`` draws every
event (tick, kind, site, columns, tiles, drift factors) from one seeded
``numpy`` generator, so a fault trace replays exactly across runs, meshes,
and recovery settings — which is what makes recovery-on vs recovery-off
goodput comparable in ``benchmarks/bench_serving.py``.

Detection
---------
``site_fingerprint`` reduces each weight to the per-(tile, col) probe
response ``R[t, j] = sum_i |codes[t, i, j]| * delta_w * scales[t, j]``
(``core.abfp.packed_tile_fingerprint``) — the digital analogue of a
calibration-ramp readout of column conductance sums.  ``detect_site``
compares the live fingerprint against the healthy baseline captured at
engine init: a relative deviation beyond ``drift_detect_rtol`` (derived
from the bf16 scale quantum, ``core.abfp.scale_storage_eps``) flags a
drifted tile; a column whose every tile reads exactly zero against a
nonzero baseline is stuck.

Repair primitives (the engine drives these; ``repro.serving.engine``)
---------------------------------------------------------------------
  * ``repair_drift``  — re-quantize-on-drift: restore ONLY the drifted
    (tile, col) scales from the clean packed copy (for weights packed
    once at init, the clean copy IS the re-quantization result).
  * ``repair_stuck``  — remap stuck columns to the replicated hot copy:
    codes + scales for those columns are re-programmed from the clean
    (spare) array.
  * shard-drop recovery is engine-level: re-shard via
    ``distributed.fault.plan_elastic_mesh`` and requeue in-flight
    requests through the scheduler.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abfp import (
    PackedWeight,
    packed_tile_fingerprint,
    scale_storage_eps,
)
from repro.models.packing import DENSE_WEIGHT_NAMES

FAULT_KINDS = ("stuck_col", "scale_drift", "shard_drop")

# Drift factors are drawn from [0.75, 0.95] ∪ [1.05, 1.25]: far outside the
# bf16 scale-storage quantum (~0.4% relative), so every injected drift is
# detectable by the fingerprint probe at the default tolerance.
_DRIFT_LO, _DRIFT_HI = 0.05, 0.25


def drift_detect_rtol() -> float:
    """Default detection tolerance: 4x the bf16 scale-storage quantum —
    far below the smallest injected drift (5%), far above storage noise."""
    return 4.0 * scale_storage_eps()


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault-injection spec the engine turns into a concrete plan.

    ``rate`` is the PER-TICK fault probability: each engine tick, one
    fault event lands somewhere in the array (site uniform over the dense
    weights, kind uniform over the enabled kinds) with probability
    ``rate``.  When ``rate > 0`` the plan always contains at least one
    event inside ``horizon`` — a sweep at 0.1% must still exercise the
    machinery.  ``horizon`` bounds the pre-drawn schedule in ticks.
    ``max_shard_drops`` caps whole-shard events per plan (a reshard
    recompiles the jitted step — one per trace is plenty to exercise it).
    """

    rate: float = 0.01
    kinds: Tuple[str, ...] = FAULT_KINDS
    seed: int = 0
    horizon: int = 512
    max_cols_per_event: int = 2
    max_tiles_per_event: int = 4
    max_shard_drops: int = 1

    def __post_init__(self):
        unknown = set(self.kinds) - set(FAULT_KINDS)
        if unknown:
            raise ValueError(
                f"unknown fault kinds {sorted(unknown)}; "
                f"expected a subset of {FAULT_KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1] (got {self.rate})")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    tick: int                       # engine tick at which the fault lands
    kind: str                       # one of FAULT_KINDS
    path: str                       # '/'-joined param path ('' = shard_drop)
    cols: Tuple[int, ...] = ()      # stuck_col: logical output columns
    tiles: Tuple[Tuple[int, int], ...] = ()  # scale_drift: (tile, col)
    factors: Tuple[float, ...] = ()          # scale_drift: multipliers
    shard: int = -1                 # shard_drop: model-axis shard index


@dataclasses.dataclass
class FaultPlan:
    """A concrete, seeded fault trace: events sorted by tick."""

    events: List[FaultEvent]
    cfg: FaultConfig

    def due(self, tick: int, cursor: int) -> Tuple[List[FaultEvent], int]:
        """Events with ``event.tick <= tick`` starting at ``cursor``;
        returns (events, new_cursor) — the engine keeps the cursor so each
        event is applied exactly once."""
        out = []
        while cursor < len(self.events) and self.events[cursor].tick <= tick:
            out.append(self.events[cursor])
            cursor += 1
        return out, cursor


# ---------------------------------------------------------------------------
# Fault sites: which param leaves can fault, addressed by path string
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSite:
    path: str
    packed: bool
    n_cols: int         # logical (un-padded) output columns
    n_padded: int       # storage columns (lane-aligned for packed)
    n_tiles: int        # ABFP K-tiles (1 for float sites)


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def fault_sites(params: Any) -> List[FaultSite]:
    """Enumerate faultable dense-weight leaves, sorted by path for
    determinism.  Packed leaves always qualify; float leaves qualify when
    their name is a known dense-matmul weight (``models.packing``)."""
    sites: List[FaultSite] = []

    def visit(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, PackedWeight):
            sites.append(FaultSite(p, True, leaf.n_cols, leaf.n_padded,
                                   leaf.num_tiles))
        elif p.split("/")[-1] in DENSE_WEIGHT_NAMES \
                and getattr(leaf, "ndim", 0) >= 2:
            n = int(leaf.shape[-1])
            sites.append(FaultSite(p, False, n, n, 1))
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, PackedWeight))
    return sorted(sites, key=lambda s: s.path)


# ---------------------------------------------------------------------------
# Plan generation: one seeded RNG draws the whole trace
# ---------------------------------------------------------------------------


def make_fault_plan(params: Any, cfg: FaultConfig, tp: int = 1) -> FaultPlan:
    """Draw a deterministic fault trace for ``params``.

    Each tick faults with probability ``cfg.rate`` (site uniform over the
    dense weights, kind uniform over the available kinds); when ``rate >
    0`` at least one event is guaranteed within the horizon.
    ``scale_drift`` applies to packed sites only; ``shard_drop`` fires at
    most ``max_shard_drops`` times and targets a uniform model-axis shard
    in [0, tp).
    """
    rng = np.random.default_rng(cfg.seed)
    sites = fault_sites(params)
    events: List[FaultEvent] = []
    if not sites or cfg.rate <= 0.0:
        return FaultPlan([], cfg)

    shard_drops = 0
    fault_ticks = list(np.flatnonzero(rng.random(cfg.horizon) < cfg.rate))
    if not fault_ticks:
        # rate > 0 must inject SOMETHING: pin one early event so even a
        # short trace at the 0.1% sweep rate measures fault handling, not
        # a lucky fault-free run.
        fault_ticks = [min(8, cfg.horizon - 1)]
    for tick in fault_ticks:
        tick = int(tick)
        site = sites[int(rng.integers(len(sites)))]
        kinds = [k for k in cfg.kinds
                 if not (k == "scale_drift" and not site.packed)]
        if shard_drops >= cfg.max_shard_drops:
            kinds = [k for k in kinds if k != "shard_drop"]
        if not kinds:
            continue
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind == "stuck_col":
            n = int(rng.integers(1, cfg.max_cols_per_event + 1))
            cols = rng.choice(site.n_cols, size=min(n, site.n_cols),
                              replace=False)
            events.append(FaultEvent(tick, kind, site.path,
                                     cols=tuple(int(c) for c in cols)))
        elif kind == "scale_drift":
            n = int(rng.integers(1, cfg.max_tiles_per_event + 1))
            ts = rng.integers(0, site.n_tiles, size=n)
            js = rng.integers(0, site.n_cols, size=n)
            mag = rng.uniform(_DRIFT_LO, _DRIFT_HI, size=n)
            sgn = rng.choice([-1.0, 1.0], size=n)
            f = 1.0 + sgn * mag
            pairs = tuple(sorted({(int(t), int(j))
                                  for t, j in zip(ts, js)}))
            events.append(FaultEvent(
                tick, kind, site.path, tiles=pairs,
                factors=tuple(float(v) for v in f[:len(pairs)])))
        else:   # shard_drop
            shard_drops += 1
            events.append(FaultEvent(tick, kind, "",
                                     shard=int(rng.integers(max(1, tp)))))
    events.sort(key=lambda e: (e.tick, e.path, e.kind))
    return FaultPlan(events, cfg)


# ---------------------------------------------------------------------------
# Injection: sharding-preserving rewrites of the served operands
# ---------------------------------------------------------------------------


def _map_site(params: Any, path: str, fn) -> Any:
    """Apply ``fn`` to the leaf at ``path``; all other leaves pass through."""

    def one(p, leaf):
        return fn(leaf) if _path_str(p) == path else leaf

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedWeight))


def _zero_cols(leaf, cols: Sequence[int]):
    idx = jnp.asarray(cols, jnp.int32)
    if isinstance(leaf, PackedWeight):
        return PackedWeight(
            leaf.codes.at[..., idx].set(0),
            leaf.scales.at[..., idx].set(0),
            leaf.k, leaf.n_cols, leaf.tile_width, leaf.bits_w,
            gains=leaf.gains)
    return leaf.at[..., idx].set(0)


def inject_stuck_cols(params: Any, path: str, cols: Sequence[int]) -> Any:
    """Stuck-at-zero output columns: codes AND scales zeroed (packed), or
    the weight columns zeroed (float)."""
    return _map_site(params, path, lambda leaf: _zero_cols(leaf, cols))


def inject_scale_drift(params: Any, path: str,
                       tiles: Sequence[Tuple[int, int]],
                       factors: Sequence[float]) -> Any:
    """Multiply the (tile, col) scales by their drift factors (bf16
    round-trip through the storage dtype, like real conductance drift
    re-read through the same DACs)."""
    t = jnp.asarray([p[0] for p in tiles], jnp.int32)
    j = jnp.asarray([p[1] for p in tiles], jnp.int32)
    f = jnp.asarray(list(factors), jnp.float32)

    def drift(leaf):
        if not isinstance(leaf, PackedWeight):
            raise ValueError(f"scale_drift targets PackedWeight (got {path})")
        s32 = leaf.scales.astype(jnp.float32)
        s32 = s32.at[..., t, j].multiply(f)
        return PackedWeight(leaf.codes, s32.astype(leaf.scales.dtype),
                            leaf.k, leaf.n_cols, leaf.tile_width,
                            leaf.bits_w, gains=leaf.gains)

    return _map_site(params, path, drift)


def inject_shard_drop(params: Any, shard: int, tp: int,
                      quant=None, mesh=None) -> Any:
    """Zero the column slice owned by model-axis shard ``shard`` on every
    weight that is column-sharded at this mesh (replicated weights survive
    on the remaining chips).  ``tp <= 1`` (or no mesh) models a
    single-array engine: the whole array of every site is lost."""
    from repro.kernels.ops import tp_shardable

    sites = {s.path for s in fault_sites(params)}

    def one(p, leaf):
        if _path_str(p) not in sites:
            return leaf
        if tp <= 1 or mesh is None:
            return _zero_cols(leaf, list(range(
                leaf.n_padded if isinstance(leaf, PackedWeight)
                else leaf.shape[-1])))
        if quant is not None and not tp_shardable(leaf, quant, mesh):
            return leaf                     # replicated: survives the loss
        width = (leaf.n_padded if isinstance(leaf, PackedWeight)
                 else leaf.shape[-1]) // tp
        cols = list(range(shard * width, (shard + 1) * width))
        return _zero_cols(leaf, cols)

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedWeight))


def apply_event(params: Any, ev: FaultEvent, *, tp: int = 1,
                quant=None, mesh=None) -> Any:
    if ev.kind == "stuck_col":
        return inject_stuck_cols(params, ev.path, ev.cols)
    if ev.kind == "scale_drift":
        return inject_scale_drift(params, ev.path, ev.tiles, ev.factors)
    if ev.kind == "shard_drop":
        return inject_shard_drop(params, ev.shard, tp, quant=quant, mesh=mesh)
    raise ValueError(f"unknown fault kind {ev.kind!r}")


# ---------------------------------------------------------------------------
# Detection: fingerprint probes against the healthy baseline
# ---------------------------------------------------------------------------


def site_fingerprint(params: Any, site: FaultSite) -> np.ndarray:
    """Per-(tile, col) probe response of one site, as host f32.

    Packed: ``core.abfp.packed_tile_fingerprint`` (leading batch axes are
    summed away — a fault on any expert/group shows in the reduction).
    Float: column L1 norm, shaped (1, N) so the (tile, col) detection code
    below is uniform."""
    leaf = _get_site(params, site.path)
    if isinstance(leaf, PackedWeight):
        fp = packed_tile_fingerprint(leaf)
        fp = fp.reshape(-1, *fp.shape[-2:]).sum(axis=0)     # (T, Np)
        return np.asarray(fp, np.float32)
    w = jnp.abs(leaf.astype(jnp.float32))
    return np.asarray(w.sum(axis=tuple(range(leaf.ndim - 1)))[None, :],
                      np.float32)


def _get_site(params: Any, path: str):
    found = []

    def one(p, leaf):
        if _path_str(p) == path:
            found.append(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedWeight))
    if not found:
        raise KeyError(f"no param leaf at {path!r}")
    return found[0]


@dataclasses.dataclass
class Detection:
    """One detection round's verdict for one site."""

    path: str
    stuck_cols: Tuple[int, ...]                 # dead columns
    drifted: Tuple[Tuple[int, int], ...]        # drifted (tile, col)

    @property
    def clean(self) -> bool:
        return not self.stuck_cols and not self.drifted


def detect_site(baseline: np.ndarray, current: np.ndarray,
                rtol: Optional[float] = None) -> Detection:
    """Compare fingerprints: exact-zero columns against a nonzero baseline
    are stuck; other relative deviations beyond ``rtol`` are drift."""
    rtol = drift_detect_rtol() if rtol is None else rtol
    base = np.maximum(baseline, 1e-30)
    rel = np.abs(current - baseline) / base
    # Stuck = every tile that HAD signal now reads exactly zero (tiles whose
    # baseline was already zero carry no information either way).
    dead_or_silent = (current == 0.0) | (baseline == 0.0)
    col_alive_base = (baseline > 0.0).any(axis=0)
    stuck = np.flatnonzero(dead_or_silent.all(axis=0) & col_alive_base)
    stuck_set = set(int(c) for c in stuck)
    drifted = [(int(t), int(j)) for t, j in zip(*np.nonzero(rel > rtol))
               if j not in stuck_set]
    return Detection("", tuple(sorted(stuck_set)), tuple(sorted(drifted)))


def fingerprint_baselines(params: Any) -> Dict[str, np.ndarray]:
    """Healthy fingerprints for every fault site (captured at engine init,
    before any injection)."""
    return {s.path: site_fingerprint(params, s) for s in fault_sites(params)}


# ---------------------------------------------------------------------------
# Repair: restore from the clean (hot-spare) copy, surgically
# ---------------------------------------------------------------------------


def repair_stuck(params: Any, clean: Any, path: str,
                 cols: Sequence[int]) -> Any:
    """Remap stuck columns onto the replicated hot copy: re-program codes +
    scales (or float columns) for exactly those columns."""
    src = _get_site(clean, path)
    idx = jnp.asarray(list(cols), jnp.int32)

    def fix(leaf):
        if isinstance(leaf, PackedWeight):
            return PackedWeight(
                leaf.codes.at[..., idx].set(src.codes[..., idx]),
                leaf.scales.at[..., idx].set(src.scales[..., idx]),
                leaf.k, leaf.n_cols, leaf.tile_width, leaf.bits_w,
                gains=leaf.gains)
        return leaf.at[..., idx].set(src[..., idx])

    return _map_site(params, path, fix)


def repair_drift(params: Any, clean: Any, path: str,
                 tiles: Sequence[Tuple[int, int]]) -> Any:
    """Re-quantize-on-drift: restore ONLY the drifted (tile, col) scales
    from the clean packed copy — codes are untouched, healthy tiles keep
    their arrays exactly (for weights quantized once at engine init the
    clean copy is by construction the re-quantization of the float
    master)."""
    src = _get_site(clean, path)
    t = jnp.asarray([p[0] for p in tiles], jnp.int32)
    j = jnp.asarray([p[1] for p in tiles], jnp.int32)

    def fix(leaf):
        if not isinstance(leaf, PackedWeight):
            raise ValueError(f"repair_drift targets PackedWeight (got {path})")
        return PackedWeight(
            leaf.codes,
            leaf.scales.at[..., t, j].set(src.scales[..., t, j]),
            leaf.k, leaf.n_cols, leaf.tile_width, leaf.bits_w,
            gains=leaf.gains)

    return _map_site(params, path, fix)
