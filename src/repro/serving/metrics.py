"""Per-request latency accounting and fleet-level serving metrics.

Timestamps come from the engine clock — simulated ticks by default (each
jitted pass advances ``tick_time``), wall-clock seconds when the engine is
built with ``clock=time.perf_counter``.  All derived latencies are plain
differences, so the unit is whatever the clock counts in.

Per request (``RequestMetrics``):
  * TTFT  — first token time minus arrival (queueing + prefill).
  * TPOT  — mean inter-token time after the first (decode cadence).
  * E2E   — finish minus arrival.
  * queue_delay — admit minus arrival (scheduler wait alone).

Per fleet (``ServingMetrics``):
  * tick utilization — live slots / capacity, sampled every jitted pass.
  * queue depth — arrived-but-unadmitted requests, sampled every pass.
  * percentile summaries (p50/p90/p99 by default) exported as JSON.
  * goodput — finished requests meeting a TTFT SLO, per clock unit.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    uid: int
    tenant: str = "default"
    prompt_len: int = 0
    arrival_time: Optional[float] = None
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_tokens: int = 0
    rejected: bool = False
    timed_out: bool = False     # deadline expired (queued or in-flight)
    corrupted: bool = False     # some token was generated while an
                                # injected fault was active and unrepaired
    requeues: int = 0           # times evicted + requeued by fault recovery
    preempts: int = 0           # times evicted under page-pool pressure
    resumes: int = 0            # re-admissions after a preemption
    shed: bool = False          # dropped by admission backpressure (a shed
                                # request is a rejection for conservation)
    retry_after: Optional[float] = None     # backoff hint stamped when shed

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None or self.arrival_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Mean time-per-output-token after the first token; None for
        single-token requests (no inter-token gap exists)."""
        if (self.finish_time is None or self.first_token_time is None
                or self.n_tokens < 2):
            return None
        return (self.finish_time - self.first_token_time) / (self.n_tokens - 1)

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None or self.arrival_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def queue_delay(self) -> Optional[float]:
        if self.admit_time is None or self.arrival_time is None:
            return None
        return self.admit_time - self.arrival_time


def percentile_summary(values: Iterable[Optional[float]],
                       percentiles: Sequence[int] = (50, 90, 99)) -> Dict:
    """``{"p50": ..., "p90": ..., "p99": ..., "mean": ..., "n": ...}`` over
    the non-None values (all None when the sample is empty)."""
    xs = [v for v in values if v is not None]
    if not xs:
        return {**{f"p{p}": None for p in percentiles},
                "mean": None, "max": None, "n": 0}
    arr = np.asarray(xs, dtype=np.float64)
    out = {f"p{p}": float(np.percentile(arr, p)) for p in percentiles}
    out["mean"] = float(arr.mean())
    out["max"] = float(arr.max())
    out["n"] = len(xs)
    return out


class ServingMetrics:
    """Event-driven collector the engine feeds; holds one RequestMetrics per
    uid (created lazily, so direct ``try_admit`` users are covered too)."""

    def __init__(self, capacity: Optional[int] = None) -> None:
        self.capacity = capacity
        self.reset()

    #: Optional ``distributed.fault.StragglerMonitor`` the engine wires in;
    #: ``summary()`` surfaces its escalation state when present.
    straggler = None

    def reset(self) -> None:
        self.requests: Dict[int, RequestMetrics] = {}
        self.ticks = 0
        self._utilization: List[float] = []
        self._queue_depth: List[int] = []
        # Paged-pool gauges (engine feeds a PoolStats per tick when paged).
        self._pool_pressure: List[float] = []
        self._pool_occupancy: List[float] = []
        self.pool_last = None       # last PoolStats observed (cumulative
                                    # prefix_hits / cow_copies / evictions)
        self.degraded_ticks = 0
        self.degraded_transitions = 0
        # Fault-tolerance counters (serving.faults / engine recovery).
        self.faults: Dict[str, int] = {
            "injected": 0,
            "injected_stuck_col": 0,
            "injected_scale_drift": 0,
            "injected_shard_drop": 0,
            "detected": 0,
            "cols_remapped": 0,
            "tiles_requantized": 0,
            "reshards": 0,
        }
        # Device-occupancy gauge (wall-clock host perf timestamps, NOT the
        # engine clock): merged union of [dispatch, delivery-done] spans
        # over the active windows the engine was serving in.
        self._device_busy = 0.0
        self._busy_mark: Optional[float] = None    # end of last merged span
        self._active = 0.0
        self._active_since: Optional[float] = None

    # -- event hooks (engine-facing) --------------------------------------
    def _req(self, uid: int) -> RequestMetrics:
        return self.requests.setdefault(uid, RequestMetrics(uid=uid))

    def on_submit(self, uid: int, *, arrival_time: float,
                  tenant: str = "default", prompt_len: int = 0) -> None:
        # A new submission of a uid is a new request: replace any completed
        # record outright so reused uids (fresh workload, same engine) do
        # not inherit stale token timestamps.
        self.requests[uid] = RequestMetrics(
            uid=uid, arrival_time=arrival_time, tenant=tenant,
            prompt_len=prompt_len)

    def on_reject(self, uid: int) -> None:
        self.requests[uid] = RequestMetrics(uid=uid, rejected=True)

    def on_admit(self, uid: int, now: float, *,
                 tenant: Optional[str] = None,
                 prompt_len: Optional[int] = None,
                 arrival_time: Optional[float] = None) -> None:
        r = self.requests.get(uid)
        if r is None or r.finish_time is not None or r.rejected:
            # Direct try_admit() (no submit) with a reused uid: start fresh.
            r = self.requests[uid] = RequestMetrics(uid=uid)
        if r.admit_time is None:
            r.admit_time = now
        if r.preempts > r.resumes:
            # This admission closes an open preemption: the request is
            # back in a slot (recompute resume), so the per-request
            # ``preempts - resumes in {0, 1}`` invariant holds again.
            r.resumes += 1
        if tenant is not None:
            r.tenant = tenant
        if prompt_len is not None:
            r.prompt_len = prompt_len
        if r.arrival_time is None:
            r.arrival_time = now if arrival_time is None else arrival_time

    def on_token(self, uid: int, now: float) -> None:
        r = self._req(uid)
        r.n_tokens += 1
        if r.first_token_time is None:
            r.first_token_time = now

    def on_finish(self, uid: int, now: float) -> None:
        self._req(uid).finish_time = now

    def on_timeout(self, uid: int, now: float) -> None:
        """Deadline expired: the request is cancelled (queued or in-flight),
        never finished — it counts toward conservation as ``timed_out``."""
        self._req(uid).timed_out = True

    def on_corrupted(self, uid: int) -> None:
        """A token was generated while an injected fault was active and
        unrepaired: the request's output cannot be trusted.  Corrupted
        requests still complete (degrade, don't crash) but are excluded
        from SLO goodput by default."""
        self._req(uid).corrupted = True

    def on_requeue(self, uid: int) -> None:
        """Fault recovery evicted this in-flight request and requeued it
        with state reset; its generation restarts from scratch, so the
        token-level timestamps (and any corruption from the discarded
        attempt) are cleared while arrival/admit history is kept."""
        r = self._req(uid)
        r.requeues += 1
        r.first_token_time = None
        r.finish_time = None
        r.n_tokens = 0
        r.corrupted = False

    def on_preempt(self, uid: int, now: float) -> None:
        """The engine evicted this in-flight request under page-pool
        pressure; it keeps every token already streamed (they are valid —
        recompute resumes the identical stream) and waits in the queue."""
        self._req(uid).preempts += 1

    def on_shed(self, uid: int, *, tenant: str = "default",
                retry_after: Optional[float] = None) -> None:
        """Admission backpressure dropped this request at submit: it was
        never queued, counts as rejected for conservation, and carries the
        retry-after hint surfaced to the client."""
        self.requests[uid] = RequestMetrics(
            uid=uid, tenant=tenant, rejected=True, shed=True,
            retry_after=retry_after)

    def on_prefix(self, n_pages: int) -> None:
        """``n_pages`` cached prompt pages attached instead of prefilled
        (the cumulative pool-side counter lives in PoolStats)."""

    def on_cow(self) -> None:
        """One copy-on-write page split (cumulative count in PoolStats)."""

    def on_degraded(self, entered: bool, now: float) -> None:
        self.degraded_transitions += 1

    def on_fault(self, kind: str) -> None:
        self.faults["injected"] += 1
        self.faults[f"injected_{kind}"] += 1

    def on_detected(self, n: int) -> None:
        self.faults["detected"] += int(n)

    def on_repair(self, action: str, n: int = 1) -> None:
        """``action`` in {cols_remapped, tiles_requantized, reshards}."""
        self.faults[action] += int(n)

    def on_device_span(self, start: float, end: float) -> None:
        """One device pass's [dispatch, delivery-done] host-clock span.
        Spans from overlapped passes interleave; busy time is the MERGED
        union (overlap counted once), so ``tick_utilization`` reads 1.0
        when the device never waits on the host between passes."""
        if end <= start:
            return
        if self._busy_mark is None or start >= self._busy_mark:
            self._device_busy += end - start
        elif end > self._busy_mark:
            self._device_busy += end - self._busy_mark
        else:
            return                      # fully inside an earlier span
        self._busy_mark = end

    def window_open(self, t: float) -> None:
        """The engine has work in flight from host-clock time ``t`` (no-op
        while a window is already open).  Idle gaps between windows —
        waiting on arrivals — don't count against device utilization."""
        if self._active_since is None:
            self._active_since = t

    def window_close(self, t: float) -> None:
        """The engine went idle: close the active window."""
        if self._active_since is not None:
            self._active += max(0.0, t - self._active_since)
            self._active_since = None

    def tick_utilization(self) -> Dict:
        """Device-busy over engine-active wall time (see on_device_span).
        A still-open window is closed virtually at the busy mark so a
        mid-run read doesn't count not-yet-delivered host time as idle."""
        active = self._active
        if self._active_since is not None and self._busy_mark is not None:
            active += max(0.0, self._busy_mark - self._active_since)
        value = (self._device_busy / active) if active > 0 else None
        return {
            "device_busy_s": self._device_busy,
            "active_s": active,
            "value": value,
        }

    def on_tick(self, now: float, live: int, capacity: int,
                queue_depth: int, *, pool=None, degraded: bool = False
                ) -> None:
        self.ticks += 1
        self._utilization.append(live / max(1, capacity))
        self._queue_depth.append(queue_depth)
        if pool is not None:
            self._pool_pressure.append(pool.pressure)
            self._pool_occupancy.append(pool.occupancy)
            self.pool_last = pool
        if degraded:
            self.degraded_ticks += 1

    # -- summaries ---------------------------------------------------------
    def finished(self) -> List[RequestMetrics]:
        return [r for r in self.requests.values()
                if r.finish_time is not None]

    def goodput(self, slo_ttft: float,
                duration: Optional[float] = None,
                include_corrupted: bool = False) -> Optional[float]:
        """Requests that finished with TTFT <= ``slo_ttft``, per clock unit.
        ``duration`` defaults to the span from earliest arrival to last
        finish.

        Corrupted requests (tokens generated under an active, unrepaired
        fault) are NOT good output and are excluded by default;
        ``include_corrupted=True`` gives the DEGRADED-MODE goodput — how
        fast the engine pushes requests out regardless of trustworthiness.
        The gap between the two is the cost of serving through faults
        without recovery."""
        fin = self.finished()
        if not fin:
            return None
        if duration is None:
            arrivals = [r.arrival_time for r in fin
                        if r.arrival_time is not None]
            duration = max(r.finish_time for r in fin) - min(arrivals)
        if duration <= 0:
            return None
        good = sum(1 for r in fin
                   if r.ttft is not None and r.ttft <= slo_ttft
                   and (include_corrupted or not r.corrupted))
        return good / duration

    def conservation(self) -> Dict:
        """The invariant every fault OR overload trace must preserve: after
        drain, ``submitted == completed + rejected + timed_out`` — a
        request can be evicted, preempted, and requeued any number of
        times, but it is never lost.  (In-flight/queued requests make the
        identity a ``<=`` mid-run.)

        With preemption the identity extends per request: every preemption
        is closed by exactly one resume or by a timeout —
        ``preempts - resumes in {0, 1}``, and the unresumed case implies
        ``timed_out`` (``preempt_ok``).  Shed requests count as rejected."""
        vals = list(self.requests.values())
        completed = sum(1 for r in vals if r.finish_time is not None)
        rejected = sum(1 for r in vals if r.rejected)
        timed_out = sum(1 for r in vals if r.timed_out)
        preempted = sum(r.preempts for r in vals)
        resumed = sum(r.resumes for r in vals)
        preempt_ok = all(
            r.preempts - r.resumes in (0, 1)
            and (r.preempts == r.resumes or r.timed_out)
            for r in vals)
        return {
            "submitted": len(self.requests),
            "completed": completed,
            "rejected": rejected,
            "timed_out": timed_out,
            "shed": sum(1 for r in vals if r.shed),
            "preempted": preempted,
            "resumed": resumed,
            "preempt_ok": preempt_ok,
            "ok": len(self.requests) == completed + rejected + timed_out,
        }

    def summary(self, percentiles: Sequence[int] = (50, 90, 99)) -> Dict:
        fin = self.finished()
        util = self._utilization
        depth = self._queue_depth
        cons = self.conservation()
        return {
            "requests": {
                "submitted": len(self.requests),
                "finished": len(fin),
                "rejected": cons["rejected"],
                "timed_out": cons["timed_out"],
                "shed": cons["shed"],
                "preempted": cons["preempted"],
                "resumed": cons["resumed"],
                "requeued": sum(1 for r in self.requests.values()
                                if r.requeues > 0),
                "corrupted": sum(1 for r in self.requests.values()
                                 if r.corrupted),
                "conservation_ok": cons["ok"],
                "preempt_ok": cons["preempt_ok"],
            },
            "pool": (None if self.pool_last is None else {
                "num_pages": self.pool_last.num_pages,
                "page_size": self.pool_last.page_size,
                "pressure_mean": float(np.mean(self._pool_pressure)),
                "pressure_max": float(np.max(self._pool_pressure)),
                "occupancy_mean": float(np.mean(self._pool_occupancy)),
                "prefix_hits": self.pool_last.prefix_hits,
                "prefix_evictions": self.pool_last.prefix_evictions,
                "cow_copies": self.pool_last.cow_copies,
                "degraded_ticks": self.degraded_ticks,
                "degraded_transitions": self.degraded_transitions,
            }),
            "faults": dict(self.faults),
            "straggler": (
                None if self.straggler is None else {
                    "escalation": self.straggler.escalation(),
                    "flagged": self.straggler.flagged,
                    "deadline_s": self.straggler.deadline(),
                }),
            "ttft": percentile_summary((r.ttft for r in fin), percentiles),
            "tpot": percentile_summary((r.tpot for r in fin), percentiles),
            "e2e": percentile_summary((r.e2e for r in fin), percentiles),
            "queue_delay": percentile_summary(
                (r.queue_delay for r in fin), percentiles),
            "ticks": self.ticks,
            "tick_utilization": self.tick_utilization(),
            "utilization": {
                "mean": float(np.mean(util)) if util else None,
                "min": float(np.min(util)) if util else None,
            },
            "queue_depth": {
                "mean": float(np.mean(depth)) if depth else None,
                "max": int(np.max(depth)) if depth else 0,
            },
        }

    def to_json(self, path: Optional[Union[str, Path]] = None,
                percentiles: Sequence[int] = (50, 90, 99), **extra) -> str:
        """Serialize ``summary()`` (plus any ``extra`` top-level fields) to
        JSON; write to ``path`` when given."""
        doc = {**self.summary(percentiles), **extra}
        text = json.dumps(doc, indent=2) + "\n"
        if path is not None:
            Path(path).write_text(text)
        return text
