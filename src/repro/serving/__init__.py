"""repro.serving — arrival-driven continuous-batching engine (ABFP or
float numerics): engine core + pluggable schedulers + SLO metrics +
fault injection/detection/recovery + paged KV pool with preemption and
admission backpressure + multi-model fleet multiplexing over per-family
ModelRunner seams + overlapped wall-clock dispatch (on-device sampling,
background token delivery) behind the DeviceStream seam."""
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.fleet import FleetEngine  # noqa: F401
from repro.serving.stream import (  # noqa: F401
    DeviceStream,
    OverlappedStream,
)
from repro.serving.runners import (  # noqa: F401
    DecoderRunner,
    EncDecRunner,
    ModelRunner,
    RecurrentRunner,
    runner_for,
)
from repro.serving.pages import (  # noqa: F401
    PagePool,
    PoolStats,
    page_table_array,
    pages_needed,
    plan_chunk,
    prefix_key,
)
from repro.serving.faults import (  # noqa: F401
    FAULT_KINDS,
    Detection,
    FaultConfig,
    FaultEvent,
    FaultPlan,
    drift_detect_rtol,
    make_fault_plan,
)
from repro.serving.metrics import (  # noqa: F401
    RequestMetrics,
    ServingMetrics,
    percentile_summary,
)
from repro.serving.scheduler import (  # noqa: F401
    POLICIES,
    FCFSScheduler,
    PriorityScheduler,
    Scheduler,
    ShortestPromptFirst,
    get_scheduler,
)
