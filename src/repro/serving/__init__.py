"""repro.serving — continuous-batching engine (ABFP or float numerics)."""
from repro.serving.engine import Request, ServingEngine  # noqa: F401
