"""Paged KV block pool: host-side allocator for the paged serving engine.

Why pages
---------
The unpaged engine allocates one worst-case ``max_len`` KV buffer per slot,
so HBM capacity is bound by the LONGEST request the engine might ever see —
the opposite of millions-of-users economics.  The paged engine instead owns
one global pool of fixed-size pages (``page_size`` tokens each, aligned to
the ABFP tile quantum: the paper's fixed-size analog tiles are the natural
block unit for the int8-quantized cache) and grows each live request page
by page as it actually decodes.  Device-side, every layer's cache becomes a
``(num_pages, page_size, ...)`` pool array; a single ``(capacity,
max_pages)`` int32 page table maps each slot's logical positions to
physical pages and is gathered INSIDE the jitted step
(``models.layers`` paged attention paths), so occupancy is data, not shape.

This module is the HOST side: a free-list allocator with reference counts,
copy-on-write, a hash-chained prefix cache (shared system prompts prefill
once), LRU eviction of cache-only pages, and per-tenant accounting for
quota enforcement.  It never touches device memory — the engine owns the
jitted page-copy / scatter ops and calls in here to decide page indices.

Invariants (property-tested in tests/test_pages.py):
  * every page is in exactly one of {free list, ref > 0};
  * ``ref[p]`` counts slot holders plus 1 if the prefix cache holds ``p``;
  * pages on the free list are never referenced by any slot or cache entry;
  * a page is only written by a slot whose ref on it is exclusive — shared
    pages are copy-on-write (``cow()``), so prefix sharing never aliases
    writes.

Sentinel convention: page index ``num_pages`` (one past the pool) marks an
unallocated page-table entry.  The jitted scatter uses ``mode="drop"`` so
writes routed to the sentinel vanish; gathers clamp and the garbage they
read is masked by per-slot lengths exactly like unpaged out-of-range slots.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` cache positions."""
    return -(-int(tokens) // int(page_size))


def prefix_key(prev: Optional[int], block: Sequence[int]) -> int:
    """Chained hash over full page-size token blocks: the key of a page
    commits to the ENTIRE prefix up to and including its tokens, so two
    prompts share a cached page iff they agree on every token before it."""
    return hash((prev, tuple(int(t) for t in block)))


@dataclasses.dataclass
class PoolStats:
    num_pages: int
    page_size: int
    free: int            # pages with ref == 0 (immediately allocatable)
    cached: int          # pages held ONLY by the prefix cache (evictable)
    held: int            # pages referenced by at least one slot
    prefix_hits: int
    prefix_evictions: int
    cow_copies: int

    @property
    def pressure(self) -> float:
        """Fraction of the pool pinned by live slots — the watermark signal
        for shedding / degraded modes (cache-only pages are reclaimable and
        do NOT count as pressure)."""
        return self.held / max(1, self.num_pages)

    @property
    def occupancy(self) -> float:
        """Fraction of the pool allocated to anything (slots + cache)."""
        return 1.0 - self.free / max(1, self.num_pages)


class PagePool:
    """Free-list page allocator with refcounts, prefix cache, and CoW."""

    def __init__(self, num_pages: int, page_size: int) -> None:
        if num_pages < 1 or page_size < 1:
            raise ValueError("pool needs >= 1 page of >= 1 token")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.sentinel = self.num_pages          # one-past-the-end marker
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self.ref = np.zeros(self.num_pages, np.int32)
        # Prefix cache: chain-key -> page, plus the reverse map and an LRU
        # order (python dicts iterate in insertion order; re-inserting on
        # touch makes the first key the least recently used).
        self._cache: Dict[int, int] = {}
        self._page_key: Dict[int, int] = {}
        self._tenant_held: Dict[str, int] = {}
        self.prefix_hits = 0
        self.prefix_evictions = 0
        self.cow_copies = 0

    # -- accounting -------------------------------------------------------
    def stats(self) -> PoolStats:
        cached_only = sum(1 for p in self._cache.values() if self.ref[p] == 1)
        held = int(np.sum(self.ref > 0)) - cached_only
        return PoolStats(
            num_pages=self.num_pages, page_size=self.page_size,
            free=len(self._free), cached=cached_only, held=held,
            prefix_hits=self.prefix_hits,
            prefix_evictions=self.prefix_evictions,
            cow_copies=self.cow_copies)

    def pressure(self) -> float:
        return self.stats().pressure

    def available(self) -> int:
        """Pages allocatable right now: the free list plus cache-only pages
        that LRU eviction can reclaim on demand."""
        return len(self._free) + sum(
            1 for p in self._cache.values() if self.ref[p] == 1)

    def tenant_held(self, tenant: str) -> int:
        return self._tenant_held.get(tenant, 0)

    # -- allocation -------------------------------------------------------
    def _evict_one_cached(self) -> bool:
        """Drop the least-recently-used cache-ONLY page back to the free
        list.  Pages a live slot still shares are skipped (evicting them
        would not free memory; the slot's ref keeps the page pinned)."""
        for key in list(self._cache):
            p = self._cache[key]
            if self.ref[p] == 1:                # cache is the only holder
                del self._cache[key]
                del self._page_key[p]
                self.ref[p] = 0
                self._free.append(p)
                self.prefix_evictions += 1
                return True
        return False

    def alloc(self, n: int, tenant: str = "default") -> Optional[List[int]]:
        """Allocate ``n`` private pages (ref = 1) for ``tenant``; evicts
        cache-only pages LRU-first when the free list runs dry.  All-or-
        nothing: returns None (and allocates nothing) if the pool cannot
        supply ``n`` pages even after eviction."""
        if n <= 0:
            return []
        while len(self._free) < n:
            if not self._evict_one_cached():
                return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self.ref[p] = 1
        self._tenant_held[tenant] = self._tenant_held.get(tenant, 0) + n
        return out

    def share(self, pages: Sequence[int], tenant: str = "default") -> None:
        """Take a reference on already-allocated pages (prefix attach)."""
        for p in pages:
            assert self.ref[p] > 0, f"sharing unallocated page {p}"
            self.ref[p] += 1
        self._tenant_held[tenant] = (
            self._tenant_held.get(tenant, 0) + len(pages))

    def release(self, pages: Sequence[int], tenant: str = "default") -> None:
        """Drop one reference per page; pages that reach ref == 0 return to
        the free list.  Pages the prefix cache still holds stay allocated
        (ref >= 1) and remain reusable until evicted."""
        for p in pages:
            assert self.ref[p] > 0, f"releasing free page {p}"
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self._free.append(p)
        held = self._tenant_held.get(tenant, 0) - len(pages)
        if held > 0:
            self._tenant_held[tenant] = held
        else:
            self._tenant_held.pop(tenant, None)

    # -- copy-on-write ----------------------------------------------------
    def cow(self, page: int, tenant: str = "default") -> Optional[int]:
        """Prepare ``page`` for writing by ``tenant``.

        Exclusive pages (ref == 1, not cached) are returned unchanged.  A
        shared or cached page is split: the caller's reference moves to a
        freshly allocated private page and the caller must copy the device
        contents (engine ``_jit_copy_page``).  Returns the page to write
        to, or None if the pool cannot supply the copy target."""
        if self.ref[page] == 1 and page not in self._page_key:
            return int(page)
        got = self.alloc(1, tenant)
        if got is None:
            return None
        # Caller held one reference on the shared page; hand it back.
        self.release([page], tenant)
        self.cow_copies += 1
        return got[0]

    # -- prefix cache -----------------------------------------------------
    def lookup(self, key: int) -> Optional[int]:
        """Cached page for a chain key (LRU-touched), else None."""
        p = self._cache.get(key)
        if p is None:
            return None
        self._cache.pop(key)
        self._cache[key] = p                     # move to MRU position
        self.prefix_hits += 1
        return p

    def register(self, key: int, page: int) -> None:
        """Publish a fully-written prompt page under its chain key.  The
        cache takes its own reference, so the page outlives the request
        that prefilled it (until LRU eviction reclaims it)."""
        if key in self._cache or page in self._page_key:
            return
        assert self.ref[page] > 0, "registering an unallocated page"
        self._cache[key] = page
        self._page_key[page] = key
        self.ref[page] += 1

    def cached_pages(self) -> int:
        return len(self._cache)

    # -- integrity (tests) ------------------------------------------------
    def check(self) -> None:
        """Assert the allocator invariants; used by the property tests."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free-list entries"
        for p in free:
            assert self.ref[p] == 0, f"free page {p} has ref {self.ref[p]}"
        for p in range(self.num_pages):
            if self.ref[p] == 0:
                assert p in free, f"leaked page {p}"
        for key, p in self._cache.items():
            assert self._page_key.get(p) == key
            assert self.ref[p] >= 1


def page_table_array(capacity: int, max_pages: int,
                     sentinel: int) -> np.ndarray:
    """Host mirror of the device page table, initialized to the sentinel
    (= ``num_pages``): every entry routes to the drop lane until a page is
    allocated, so dead or short slots can never scatter into live pages."""
    return np.full((capacity, max_pages), sentinel, np.int32)


def plan_chunk(slot_len: int, need: int, pages: List[int],
               page_size: int) -> Tuple[int, List[int]]:
    """For a slot about to append ``need`` tokens at ``slot_len``: returns
    ``(extra_pages, write_page_indices)`` — how many new pages must be
    allocated and which HELD page indices fall in the write range (the CoW
    guard checks those for shared refs)."""
    required = pages_needed(slot_len + need, page_size)
    first = slot_len // page_size
    last = (slot_len + max(need, 1) - 1) // page_size
    writes = [j for j in range(first, min(last + 1, len(pages)))]
    return max(0, required - len(pages)), writes
