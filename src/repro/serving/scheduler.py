"""Pluggable admission schedulers for the continuous-batching engine.

A scheduler owns the waiting queue between ``ServingEngine.submit()`` and
slot admission.  Every ``poll()`` the engine asks ``pop(now)`` for the next
request to admit; only requests that have *arrived* (``arrival_time <=
now``) are eligible, so the same scheduler drives both the simulated-clock
open-loop path (deterministic tests, trace replay) and wall-clock serving.

Policies decide admission ORDER; the queue itself is preemption-AWARE:
the paged engine requeues preempted requests here (``requeue`` preserves
arrival order, so a victim re-admits ahead of younger traffic), peeks the
head under an admissibility filter for priority page claims, and can
``remove`` a specific request it is about to admit by preempting a victim.
``pop``/``peek`` accept an optional ``admissible`` predicate — requests
failing it (per-tenant page quota, pool exhaustion) are SKIPPED, not
dequeued, so a blocked tenant never head-of-line blocks the rest.

  * ``fcfs``      — first-come-first-served on (arrival_time, submit order).
  * ``sjf``       — shortest-prompt-first among arrived requests (minimizes
                    mean TTFT under prefill-dominated load; starvation-free
                    only under finite workloads).
  * ``priority``  — highest ``Request.priority`` first; WITHIN a priority
                    class, tenants round-robin on fewest-admissions-so-far,
                    so one tenant flooding the queue cannot starve another
                    at the same priority (per-tenant fairness under
                    saturation).

Queues here are small (hundreds at most) and admission happens at most
``capacity`` times per tick, so the linear-scan ``pop`` is deliberate —
an indexed heap would buy nothing and cost the invariant clarity.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple, Union

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.serving.engine import Request


class Scheduler(abc.ABC):
    """Base queue: stable submit order plus a policy-defined sort key."""

    name = "base"

    def __init__(self) -> None:
        self._queue: List["Request"] = []
        self._order: Dict[int, int] = {}    # id(req) -> submit sequence
        self._seq = 0

    def add(self, req: "Request") -> None:
        self._order[id(req)] = self._seq
        self._seq += 1
        self._queue.append(req)

    def __len__(self) -> int:
        return len(self._queue)

    def pending(self, now: float) -> int:
        """Queued requests that have arrived by ``now`` (queue depth)."""
        return sum(1 for r in self._queue if r.arrival_time <= now)

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival among queued requests (for idle clock jumps)."""
        return min((r.arrival_time for r in self._queue), default=None)

    def requeue(self, req: "Request") -> None:
        """Re-add an in-flight request evicted by fault recovery (its slot
        state died with a shard).  ``arrival_time`` is preserved, so
        arrival-ordered policies re-admit it ahead of younger traffic —
        a recovered request never goes to the back of the line."""
        self.add(req)

    def expire(self, now: float) -> List["Request"]:
        """Remove and return queued requests whose deadline has passed —
        they will never be admitted, so the engine marks them timed out
        instead of letting them rot in the queue."""
        out = [r for r in self._queue
               if getattr(r, "deadline", None) is not None
               and r.deadline <= now]
        for r in out:
            self._queue.remove(r)
            self._order.pop(id(r))
        return out

    def _arrived(self, now: float, admissible=None) -> List["Request"]:
        out = [r for r in self._queue if r.arrival_time <= now]
        if admissible is not None:
            out = [r for r in out if admissible(r)]
        return out

    def peek(self, now: float, admissible=None) -> Optional["Request"]:
        """The request ``pop`` would return, without removing it — the
        engine's priority-claim path peeks before deciding to preempt."""
        arrived = self._arrived(now, admissible)
        return min(arrived, key=self._key) if arrived else None

    def pop(self, now: float, admissible=None) -> Optional["Request"]:
        """Remove and return the next request to admit, or None if nothing
        has arrived by ``now`` (or nothing passes ``admissible``)."""
        arrived = self._arrived(now, admissible)
        if not arrived:
            return None
        req = min(arrived, key=self._key)
        self.remove(req)
        return req

    def remove(self, req: "Request") -> None:
        """Dequeue a specific request the engine is admitting out-of-band
        (priority claim after preempting a victim); fires ``_on_pop`` so
        per-tenant fairness accounting stays consistent."""
        self._queue.remove(req)
        self._order.pop(id(req))
        self._on_pop(req)

    def _on_pop(self, req: "Request") -> None:
        """Policy hook: called after ``req`` is chosen for admission."""

    @abc.abstractmethod
    def _key(self, req: "Request") -> Tuple:
        """Sort key over arrived requests; the minimum is admitted next."""


class FCFSScheduler(Scheduler):
    name = "fcfs"

    def _key(self, req: "Request") -> Tuple:
        return (req.arrival_time, self._order[id(req)])


class ShortestPromptFirst(Scheduler):
    name = "sjf"

    def _key(self, req: "Request") -> Tuple:
        return (len(req.prompt), req.arrival_time, self._order[id(req)])


class PriorityScheduler(Scheduler):
    """Strict priority between classes, tenant-fair within a class."""

    name = "priority"

    def __init__(self) -> None:
        super().__init__()
        self._tenant_admits: Dict[str, int] = {}

    def _key(self, req: "Request") -> Tuple:
        return (-req.priority,
                self._tenant_admits.get(req.tenant, 0),
                req.arrival_time,
                self._order[id(req)])

    def _on_pop(self, req: "Request") -> None:
        self._tenant_admits[req.tenant] = (
            self._tenant_admits.get(req.tenant, 0) + 1)


POLICIES = {
    FCFSScheduler.name: FCFSScheduler,
    ShortestPromptFirst.name: ShortestPromptFirst,
    PriorityScheduler.name: PriorityScheduler,
}


def get_scheduler(policy: Union[str, Scheduler]) -> Scheduler:
    """Resolve a policy name (``fcfs`` / ``sjf`` / ``priority``) or pass an
    already-constructed Scheduler through."""
    if isinstance(policy, Scheduler):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; "
            f"expected one of {sorted(POLICIES)}") from None
