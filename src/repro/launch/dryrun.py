import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing module: jax locks the device count at first
#   init.  setdefault lets the mini-test override with a smaller count.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()
then records memory_analysis(), cost_analysis(), and the collective traffic
parsed from the compiled HLO into experiments/dryrun/<cell>.json — the
roofline analysis (benchmarks/roofline.py) reads these artifacts.

Cells:
  train_4k      -> train_step (AdamW + ZeRO-1 + remat + 4 microbatches)
  prefill_32k   -> prefill (teacher-forced forward)
  decode_32k    -> decode_step with a 32k KV cache
  long_500k     -> decode_step at 524288 context (ssm/hybrid only)

``--quant abfp`` lowers the paper-faithful ABFP-simulation step instead
(column-parallel weight sharding so ABFP tiles stay shard-local; QAT for
train cells, ABFP inference for serve cells).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--quant abfp]
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.core.abfp import QuantConfig
from repro.distributed.sharding import (
    abfp_param_spec_tree,
    batch_spec,
    decode_state_spec_tree,
    param_spec_tree,
    zero1_spec,
)
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import loop_aware_costs
from repro.models import init_decode_state, init_params
from repro.models.lm import _pattern
from repro.optim.optimizers import AdamW, constant
from repro.training.train_lib import TrainConfig, TrainState, make_train_step

# Artifact output dir; REPRO_DRYRUN_ART_DIR overrides so ad-hoc runs (e.g.
# the mini integration tests) don't pollute the real roofline artifact set.
ART_DIR = os.environ.get("REPRO_DRYRUN_ART_DIR") or os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _quant_cfg(quant: str) -> QuantConfig:
    if quant == "float":
        return QuantConfig(mode="float")
    # Paper-faithful ABFP: tile 128, gain 8, 8/8/8 bits, 0.5 LSB ADC noise —
    # the configuration the paper's Sec. VI analysis selects.
    return QuantConfig(mode="abfp_ref", tile_width=128, gain=8.0,
                       bits_w=8, bits_x=8, bits_y=8, noise_lsb=0.5)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell —
    weak-type-correct, shardable, no device allocation."""
    mcfg = get_config(arch)
    sc = SHAPES[shape_name]
    b, s = sc.global_batch, sc.seq_len
    out: dict = {}
    if sc.kind == "train":
        if mcfg.frontend == "vision_stub":
            out["embeds"] = jax.ShapeDtypeStruct((b, s, mcfg.d_model),
                                                 jnp.bfloat16)
            out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
        if mcfg.is_encoder_decoder:
            out["encoder_features"] = jax.ShapeDtypeStruct(
                (b, s, mcfg.d_model), jnp.bfloat16)
    elif sc.kind == "prefill":
        if mcfg.frontend == "vision_stub":
            out["tokens"] = jax.ShapeDtypeStruct((b, s, mcfg.d_model),
                                                 jnp.bfloat16)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if mcfg.is_encoder_decoder:
            out["encoder_features"] = jax.ShapeDtypeStruct(
                (b, s, mcfg.d_model), jnp.bfloat16)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    return out


def _abstract_params(mcfg):
    return jax.eval_shape(lambda k: init_params(k, mcfg),
                          jax.random.PRNGKey(0))


def _param_shardings(mcfg, mesh, quant: str):
    a = _abstract_params(mcfg)
    tree = (abfp_param_spec_tree(a, mesh) if quant == "abfp"
            else param_spec_tree(a, mesh))
    return a, _ns(mesh, tree)


def _run_cell(arch: str, shape_name: str, mesh, mesh_name: str, quant: str,
              save: bool = True, kv_quant: bool = False,
              compression: str = None, microbatches: int = 4,
              tag: str = "") -> dict:
    import dataclasses

    t0 = time.time()
    sc = SHAPES[shape_name]
    mcfg = dataclasses.replace(get_config(arch), remat=(sc.kind == "train"),
                               kv_quant=kv_quant)
    qc = _quant_cfg(quant)
    abstract_params, p_shard = _param_shardings(mcfg, mesh, quant)
    specs = input_specs(arch, shape_name)

    if sc.kind == "train":
        opt = AdamW(schedule=constant(1e-6))
        tcfg = TrainConfig(microbatches=microbatches, quant=qc,
                           compression=compression)
        # MoE archs use the expert-parallel shard_map path over 'model'.
        _, train_step = make_train_step(
            mcfg, opt, tcfg, mesh=mesh if mcfg.num_experts else None)

        a_state = jax.eval_shape(
            lambda p: TrainState(p, opt.init(p), None, jnp.zeros((), jnp.int32)),
            abstract_params)
        pspec_tree = (abfp_param_spec_tree(abstract_params, mesh)
                      if quant == "abfp"
                      else param_spec_tree(abstract_params, mesh))
        z1 = jax.tree.map(
            lambda s, p: zero1_spec(s, p.shape, mesh),
            pspec_tree, abstract_params, is_leaf=lambda x: isinstance(x, P))
        state_shard = TrainState(
            params=_ns(mesh, pspec_tree),
            opt_state=type(a_state.opt_state)(
                step=NamedSharding(mesh, P()),
                mu=_ns(mesh, z1), nu=_ns(mesh, z1), master=_ns(mesh, z1)),
            ef=None,
            step=NamedSharding(mesh, P()),
        )
        a_batch = dict(specs)
        batch_shard = {
            k: NamedSharding(mesh, batch_spec(mesh, v.shape))
            for k, v in specs.items()}

        jitted = jax.jit(
            train_step,
            in_shardings=(state_shard, batch_shard, NamedSharding(mesh, P())),
            out_shardings=(state_shard, None),
            donate_argnums=(0,))               # state buffers alias in-place
        with mesh:
            lowered = jitted.lower(
                a_state, a_batch,
                jax.ShapeDtypeStruct((2,), jnp.uint32))

    elif sc.kind == "prefill":
        # MoE archs route through the expert-parallel shard_map (perf
        # iteration: the GSPMD-partitioned single-shard MoE path was the
        # most collective-bound cell in the grid — EXPERIMENTS.md §Perf).
        moe_mesh = mesh if mcfg.num_experts else None

        def prefill(params, batch, key):
            # Serving prefill: hidden states -> LAST-position logits only
            # (full (B, 32k, 256k-vocab) logits would be TBs; decode starts
            # from the final position).
            from repro.models import forward
            from repro.models.layers import Numerics
            from repro.models.lm import lm_head_logits
            nx = Numerics(qc, key)
            hidden, _ = forward(params, batch["tokens"], mcfg, nx,
                                encoder_features=batch.get("encoder_features"),
                                return_hidden=True, mesh=moe_mesh)
            return lm_head_logits(params, hidden[:, -1:], mcfg, nx)[:, 0]

        a_batch = {"tokens": specs["tokens"]}
        batch_shard = {"tokens": NamedSharding(
            mesh, batch_spec(mesh, specs["tokens"].shape))}
        if "encoder_features" in specs:
            a_batch["encoder_features"] = specs["encoder_features"]
            batch_shard["encoder_features"] = NamedSharding(
                mesh, batch_spec(mesh, specs["encoder_features"].shape))
        out_spec = batch_spec(mesh, (sc.global_batch, mcfg.vocab_size))
        jitted = jax.jit(
            prefill,
            in_shardings=(p_shard, batch_shard, NamedSharding(mesh, P())),
            out_shardings=NamedSharding(mesh, out_spec))
        with mesh:
            lowered = jitted.lower(abstract_params, a_batch,
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))

    else:  # decode
        a_state = jax.eval_shape(
            lambda: init_decode_state(mcfg, sc.global_batch, sc.seq_len))
        s_shard = _ns(mesh, decode_state_spec_tree(a_state, mesh))

        enc_kv_spec = None
        a_enc_kv = None
        if mcfg.is_encoder_decoder:
            _, n_groups, _ = _pattern(mcfg)
            kh, hd = mcfg.num_kv_heads, mcfg.resolved_head_dim
            kv_sd = jax.ShapeDtypeStruct(
                (n_groups, sc.global_batch, sc.seq_len, kh, hd), jnp.bfloat16)
            a_enc_kv = [(kv_sd, kv_sd)]
            axis = "model" if hd % mesh.shape["model"] == 0 else None
            bax = batch_spec(mesh, (sc.global_batch,))[0]
            spec = P(None, bax, None, None, axis)
            enc_kv_spec = [(NamedSharding(mesh, spec),) * 2]

        def decode(params, state, token, key):
            from repro.models import decode_step
            from repro.models.layers import Numerics
            nx = Numerics(qc, key)
            return decode_step(params, state, token, mcfg, nx, enc_kv=None)

        if mcfg.is_encoder_decoder:
            def decode(params, state, token, key, enc_kv):  # noqa: F811
                from repro.models import decode_step
                from repro.models.layers import Numerics
                nx = Numerics(qc, key)
                return decode_step(params, state, token, mcfg, nx,
                                   enc_kv=enc_kv)

        in_sh = [p_shard, s_shard,
                 NamedSharding(mesh, batch_spec(mesh, (sc.global_batch,))),
                 NamedSharding(mesh, P())]
        args = [abstract_params, a_state, specs["token"],
                jax.ShapeDtypeStruct((2,), jnp.uint32)]
        if mcfg.is_encoder_decoder:
            in_sh.append(enc_kv_spec)
            args.append(a_enc_kv)
        jitted = jax.jit(
            decode, in_shardings=tuple(in_sh),
            out_shardings=(NamedSharding(
                mesh, batch_spec(mesh, (sc.global_batch, mcfg.vocab_size))),
                s_shard),
            donate_argnums=(1,))               # KV cache updates in place
        with mesh:
            lowered = jitted.lower(*args)

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax < 0.5 returns a one-element list of dicts; newer returns the dict.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    # Loop-aware costs: cost_analysis() counts while bodies (= every
    # lax.scan: layers, microbatches, attention chunks) only ONCE; the HLO
    # re-analysis multiplies by known_trip_count.  See hlo_analysis.py.
    la = loop_aware_costs(hlo)
    colls = la["collectives"]
    compile_s = time.time() - t0

    chips = mesh.devices.size
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)
    # Per-device steady-state bytes: weights+state (aliased args) + temps.
    live = (mem_fields.get("argument_size_in_bytes", 0)
            + mem_fields.get("temp_size_in_bytes", 0)
            + mem_fields.get("output_size_in_bytes", 0)
            - mem_fields.get("alias_size_in_bytes", 0))

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "quant": quant + tag, "kind": sc.kind, "chips": int(chips),
        "flops_per_device": la["flops"],
        "hbm_bytes_per_device": la["hbm_bytes"],
        "hbm_bytes_pessimistic": la.get("hbm_bytes_pessimistic", -1.0),
        "flops_naive": float(cost.get("flops", -1.0)) if cost else -1.0,
        "hbm_bytes_naive": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "collectives": colls,
        "memory": mem_fields,
        "live_bytes_per_device": int(live),
        "fits_16g": bool(live <= mesh_lib.HBM_PER_CHIP),
        "compile_seconds": round(compile_s, 1),
    }
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({quant}): "
          f"compiled in {compile_s:.0f}s; live/device = {live/2**30:.2f} GiB; "
          f"flops/device = {result['flops_per_device']:.3e}; "
          f"coll bytes/device = {colls['total']['bytes']:.3e}")
    print(f"  memory_analysis: {mem_fields}")

    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}__{quant}{tag}.json"
        with open(os.path.join(ART_DIR, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def runnable_cells():
    """The 40-cell grid minus documented skips (DESIGN.md)."""
    cells = []
    for arch in list_archs():
        mcfg = get_config(arch)
        for shape_name in SHAPES:
            if shape_name == "long_500k" and not mcfg.supports_long_context_decode:
                continue  # full-attention archs skip long_500k (DESIGN.md)
            cells.append((arch, shape_name))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", choices=("float", "abfp"), default="float")
    ap.add_argument("--mesh-shape", default=None,
                    help="override, e.g. '4,2' (mini test)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8-ABFP KV cache (beyond-paper; decode cells)")
    ap.add_argument("--compression", choices=("bf16", "int8"), default=None,
                    help="DP gradient compression (train cells)")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--tag", default="",
                    help="suffix for the artifact filename (perf iterations)")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        mesh = jax.make_mesh(shape, axes)
        mesh_name = "x".join(map(str, shape))
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
        mesh_name = "2x16x16" if args.multi_pod else "16x16"

    cells = runnable_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape_name in cells:
        try:
            _run_cell(arch, shape_name, mesh, mesh_name, args.quant,
                      kv_quant=args.kv_quant, compression=args.compression,
                      microbatches=args.microbatches, tag=args.tag)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape_name, repr(e)))
            print(f"[dryrun] FAILED {arch} x {shape_name}: {e}")
            traceback.print_exc()
            if not args.continue_on_error:
                return 1
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        return 1
    print(f"[dryrun] all {len(cells)} cells compiled OK on {mesh_name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
