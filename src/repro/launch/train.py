"""Training driver: end-to-end launcher with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --reduced --ckpt-dir /tmp/run1 --resume auto

Wires together: config -> (reduced) model -> synthetic data -> train step
(float / QAT / DNF) -> checkpointing (atomic, keep-k, auto-resume) ->
straggler monitor -> restart policy.  On a multi-host pod the same driver
runs under ``jax.distributed.initialize()``; in this container it runs
single-process (the dry-run covers the production mesh).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro import checkpoint as ckpt
from repro.configs import get_config, smoke_config
from repro.core.abfp import QuantConfig
from repro.data import DataConfig, batch_at_step
from repro.distributed.fault import RestartPolicy, StragglerMonitor
from repro.models import init_params, param_count
from repro.optim import AdamW, cosine_one_cycle
from repro.training.train_lib import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-sized)")
    ap.add_argument("--quant", choices=("float", "qat"), default="float")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", choices=("none", "bf16", "int8"),
                    default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=("auto", "never"), default="auto")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mcfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    if mcfg.frontend == "vision_stub" or mcfg.is_encoder_decoder:
        mcfg = dataclasses.replace(mcfg, frontend="none",
                                   is_encoder_decoder=False,
                                   num_encoder_layers=0)
        print("[train] stub-frontend arch: training the text backbone")

    dcfg = DataConfig(vocab_size=mcfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    quant = (QuantConfig(mode="abfp_ref", tile_width=128, gain=8.0,
                         noise_lsb=0.5) if args.quant == "qat"
             else QuantConfig(mode="float"))
    tcfg = TrainConfig(
        microbatches=args.microbatches,
        compression=None if args.compression == "none" else args.compression,
        quant=quant)
    opt = AdamW(schedule=cosine_one_cycle(args.lr, args.steps))
    init_state, train_step = make_train_step(mcfg, opt, tcfg)

    params = init_params(jax.random.PRNGKey(args.seed), mcfg)
    print(f"[train] {args.arch} ({'reduced' if args.reduced else 'full'}): "
          f"{param_count(params)/1e6:.1f}M params, quant={args.quant}")
    state = init_state(params)

    start_step = 0
    if args.ckpt_dir and args.resume == "auto" \
            and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step, extra = ckpt.restore(args.ckpt_dir, state)
        print(f"[train] resumed from step {start_step}")

    step_jit = jax.jit(train_step, donate_argnums=(0,))
    monitor = StragglerMonitor()
    policy = RestartPolicy()

    for step in range(start_step, args.steps):
        batch = batch_at_step(dcfg, step)
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), step)
        t0 = time.time()
        state, metrics = step_jit(state, batch, key)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        if monitor.observe(dt):
            print(f"[train] step {step}: straggler breach ({dt:.2f}s); "
                  f"escalation={monitor.escalation()}")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step}: loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(args.ckpt_dir, step + 1, state,
                             extra={"data_step": step + 1})
            print(f"[train] checkpoint -> {path}")

    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state,
                  extra={"data_step": args.steps})
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
