"""Serving driver: batched requests through the continuous-batching engine,
in FLOAT or ABFP (the AMS-deployment simulation).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --requests 16 --quant abfp
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.abfp import QuantConfig
from repro.models import init_params, param_count
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quant",
                    choices=("float", "abfp", "abfp-kernel", "abfp-packed"),
                    default="float",
                    help="abfp: pure-jnp scan; abfp-kernel: fused Pallas; "
                         "abfp-packed: weights quantized once at init, "
                         "packed Pallas kernel per tick")
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--gain", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--no-chunked", action="store_true",
                    help="legacy prefill-in-decode: one prompt token per "
                         "decode tick instead of bucketed prefill chunks")
    ap.add_argument("--prefill-chunks", default="16,64,128",
                    help="comma-separated chunk buckets for prefill passes "
                         "(one jit compile each)")
    args = ap.parse_args()

    mcfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), mcfg)
    mode = {"float": "float", "abfp": "abfp_ref",
            "abfp-kernel": "abfp_kernel",
            "abfp-packed": "abfp_packed"}[args.quant]
    quant = (QuantConfig(mode=mode, tile_width=args.tile,
                         gain=args.gain, noise_lsb=0.5)
             if mode != "float" else QuantConfig(mode="float"))

    print(f"[serve] {args.arch}: {param_count(params)/1e6:.1f}M params, "
          f"quant={args.quant}")
    eng = ServingEngine(params, mcfg, capacity=args.capacity,
                        max_len=args.max_len, quant=quant, seed=args.seed,
                        chunked=not args.no_chunked,
                        prefill_chunks=tuple(
                            int(c) for c in args.prefill_chunks.split(",")))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, mcfg.vocab_size,
                                        args.prompt_len).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    tokens = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s, {eng.ticks} ticks)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
