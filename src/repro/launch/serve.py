"""Serving driver: closed-loop batch or arrival-driven open-loop serving
through the continuous-batching engine, in FLOAT or ABFP (the
AMS-deployment simulation).

Closed loop (historical behavior — admit everything, run to completion):

    PYTHONPATH=src python -m repro.launch.serve --requests 16 --quant abfp

Open loop (Poisson arrivals on the simulated clock, scheduling policy,
SLO metrics):

    PYTHONPATH=src python -m repro.launch.serve --arrival-rate 2.0 \
        --policy sjf --tenants 2

Trace replay: ``--trace FILE`` where FILE is a JSON list of requests,
each ``{"arrival_time": float, "prompt": [ints]}`` or
``{"arrival_time": float, "prompt_len": int}`` plus optional
``max_new_tokens`` / ``priority`` / ``tenant`` / ``temperature``.

Open-loop runs print p50/p99 TTFT, TPOT, and E2E in simulated ticks (one
tick = one jitted pass) plus goodput against ``--slo-ttft``;
``--metrics-out`` dumps the full percentile summary as JSON
(see ``repro.serving.metrics``).

Sharded serving: ``--mesh dp,tp`` builds a (data, model) device mesh and
runs the engine tensor-parallel (column-parallel weights over 'model',
slot state over 'data').  When the host exposes fewer than dp*tp devices
the driver forces placeholder CPU devices via
``--xla_force_host_platform_device_count`` BEFORE first jax use, so the
whole path runs on CPU CI:

    PYTHONPATH=src python -m repro.launch.serve --mesh 2,4 --quant abfp-packed

Greedy decode under any mesh shape emits bit-identical tokens to the
single-device engine for the same seed (tests/test_sharded_serving.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_config, list_archs, smoke_config
from repro.core.abfp import QuantConfig
from repro.models import frontends, init_params, param_count
from repro.serving import FaultConfig, Request, ServingEngine
from repro.serving.runners import EncDecRunner, runner_for


def parse_mesh(arg: Optional[str]) -> Optional[Tuple[int, int]]:
    """'dp,tp' -> (dp, tp); None passes through (single-device engine)."""
    if arg is None:
        return None
    try:
        dp, tp = (int(v) for v in arg.split(","))
    except ValueError:
        raise SystemExit(f"--mesh expects 'dp,tp' (got {arg!r})")
    if dp < 1 or tp < 1:
        raise SystemExit(f"--mesh axes must be >= 1 (got {arg!r})")
    return dp, tp


def force_host_devices(n: int) -> None:
    """Ensure >= n CPU devices exist, forcing placeholders if needed.

    Must run BEFORE anything initializes the jax backend: XLA reads
    ``--xla_force_host_platform_device_count`` exactly once, at first use.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def resolve_archs(args) -> List[str]:
    """Validated arch list: ``--archs a,b,c`` (fleet) or ``--arch`` (single).
    Unknown names fail FAST with the registry listed — before any params
    are initialized or jax warms up."""
    names = ([a.strip() for a in args.archs.split(",") if a.strip()]
             if args.archs else [args.arch])
    known = sorted(list_archs())
    bad = [a for a in names if a not in known]
    if bad or not names:
        what = f"unknown arch(es) {bad}" if bad else "no archs given"
        raise SystemExit(
            f"[serve] {what}; registered archs: {', '.join(known)}")
    return names


def parse_model_split(arg: Optional[str]) -> Optional[dict]:
    """'name=slots,name=slots' -> {name: slots}; None passes through."""
    if arg is None:
        return None
    out = {}
    for part in arg.split(","):
        if not part.strip():
            continue
        try:
            name, slots = part.split("=")
            out[name.strip()] = int(slots)
        except ValueError:
            raise SystemExit(
                f"--model-split expects 'name=slots,...' (got {arg!r})")
    return out or None


def attach_features(reqs: List[Request], runners: dict, seed: int) -> None:
    """Stub frontend features for requests routed to enc-dec lanes: each
    request gets its own deterministic (enc_len, d_model) audio-frame
    embedding keyed by (seed, uid)."""
    for r in reqs:
        runner = runners.get(r.model)
        if not isinstance(runner, EncDecRunner):
            continue
        key = jax.random.fold_in(jax.random.PRNGKey(seed), r.uid)
        r.features = np.asarray(
            frontends.audio_stub_features(
                key, 1, runner.enc_len, runner.mcfg.d_model)[0],
            np.float32)


def poisson_workload(mcfg, args, rng: np.random.Generator) -> List[Request]:
    """Mixed-tenant Poisson arrivals: exponential inter-arrival gaps at
    ``--arrival-rate`` requests per simulated tick, prompt lengths drawn
    uniformly from [1, 2 * --prompt-len - 1]."""
    gaps = rng.exponential(1.0 / args.arrival_rate, args.requests)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(1, max(2, 2 * args.prompt_len)))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(1, mcfg.vocab_size, plen).tolist(),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
            arrival_time=float(arrivals[i]),
            priority=int(rng.integers(0, 3)),
            tenant=f"t{int(rng.integers(args.tenants))}"))
    return reqs


def trace_workload(mcfg, args, rng: np.random.Generator) -> List[Request]:
    entries = json.loads(open(args.trace).read())
    reqs = []
    for i, e in enumerate(entries):
        prompt = e.get("prompt")
        if prompt is None:
            plen = int(e.get("prompt_len", args.prompt_len))
            prompt = rng.integers(1, mcfg.vocab_size, plen).tolist()
        reqs.append(Request(
            uid=i, prompt=list(prompt),
            max_new_tokens=int(e.get("max_new_tokens", args.max_new)),
            temperature=float(e.get("temperature", args.temperature)),
            arrival_time=float(e.get("arrival_time", 0.0)),
            priority=int(e.get("priority", 0)),
            tenant=str(e.get("tenant", "default"))))
    return reqs


def serve_fleet(built: dict, quant: QuantConfig, mesh, args) -> None:
    """Multi-model fleet serving: one lane per ``--archs`` entry on a
    shared clock, requests routed round-robin across models (enc-dec lanes
    get stub frontend features per request)."""
    runners = {name: runner_for(cfg) for name, (_, cfg) in built.items()}
    eng = ServingEngine(
        models={name: (p, cfg, runners[name])
                for name, (p, cfg) in built.items()},
        capacity=args.capacity,
        model_split=parse_model_split(args.model_split),
        max_len=args.max_len, quant=quant, seed=args.seed,
        chunked=not args.no_chunked, policy=args.policy,
        prefill_chunks=tuple(int(c) for c in args.prefill_chunks.split(",")),
        mesh=mesh, paged=args.paged, page_size=args.page_size,
        pool_pages=args.pool_pages, prefix_cache=not args.no_prefix_cache)
    lanes = {n: l.capacity for n, l in eng.lanes.items()}
    print(f"[serve] fleet: {len(built)} models, slots {lanes}, "
          f"quant={args.quant}, policy={args.policy}")

    rng = np.random.default_rng(args.seed)
    names = list(built)
    if args.arrival_rate is not None or args.trace is not None:
        reqs = (trace_workload(built[names[0]][1], args, rng) if args.trace
                else poisson_workload(built[names[0]][1], args, rng))
    else:
        reqs = [Request(uid=i,
                        prompt=rng.integers(
                            1, built[names[0]][1].vocab_size,
                            args.prompt_len).tolist(),
                        max_new_tokens=args.max_new,
                        temperature=args.temperature)
                for i in range(args.requests)]
    for i, r in enumerate(reqs):
        r.model = names[i % len(names)]
        # Prompts must fit every lane's vocab (smallest wins).
        vmax = built[r.model][1].vocab_size
        r.prompt = [t % (vmax - 1) + 1 for t in r.prompt]
    attach_features(reqs, runners, args.seed)

    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    tokens = sum(len(r.generated) for r in done)
    print(f"[serve] fleet: {len(done)} requests, {tokens} tokens in "
          f"{dt:.1f}s ({tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{eng.ticks} ticks)")

    def fmt(d, key):
        v = d[key]
        return "-" if v is None else f"{v:.2f}"

    summaries = eng.summary()
    cons = eng.conservation()
    for name in names:
        s, c = summaries[name], cons[name]
        print(f"  {name}: TTFT p50 {fmt(s['ttft'], 'p50')} / "
              f"p99 {fmt(s['ttft'], 'p99')} | TPOT p50 "
              f"{fmt(s['tpot'], 'p50')} | completed "
              f"{c['completed']}/{c['submitted']} "
              f"(conservation_ok {c['ok']})")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"fleet": {n: summaries[n] for n in names},
                       "conservation": cons}, f, indent=2, default=str)
        print(f"[serve] wrote {args.metrics_out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    help="model architecture (see repro.configs.list_archs)")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch list — serve a MULTI-MODEL "
                         "FLEET (one lane per arch, multiplexed on a shared "
                         "clock; requests route round-robin across models)")
    ap.add_argument("--model-split", default=None,
                    help="'name=slots,...' per-model slot overrides for "
                         "--archs (remaining capacity splits near-equally)")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced (smoke) shapes — the default")
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="full-size architecture config")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quant",
                    choices=("float", "abfp", "abfp-kernel", "abfp-packed"),
                    default="float",
                    help="abfp: pure-jnp scan; abfp-kernel: fused Pallas; "
                         "abfp-packed: weights quantized once at init, "
                         "packed Pallas kernel per tick")
    ap.add_argument("--fused", action="store_true",
                    help="abfp_fused serving: packed weights carry per-tile "
                         "ADC gains (capped by --gain) and decode ticks run "
                         "the fused QKV + quantized-KV-attention kernels "
                         "(kernels.abfp_decode_fused); overrides --quant "
                         "and serves with a quantized (int8) KV cache")
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--gain", type=float, default=8.0,
                    help="ADC gain G (paper Sec. IV): scalar output "
                         "amplification in abfp modes; with --fused, the "
                         "per-tile adaptive gain cap (gains are "
                         "powers of two in [1, G] chosen per weight tile)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-chunked", action="store_true",
                    help="legacy prefill-in-decode: one prompt token per "
                         "decode tick instead of bucketed prefill chunks")
    ap.add_argument("--prefill-chunks", default="16,64,128",
                    help="comma-separated chunk buckets for prefill passes "
                         "(one jit compile each)")
    # Open-loop serving (arrival-driven; omit both for the closed loop).
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrival rate in requests per simulated "
                         "tick; enables the open-loop submit/poll path")
    ap.add_argument("--trace", default=None,
                    help="JSON trace of requests to replay (see module "
                         "docstring for the schema)")
    ap.add_argument("--policy", choices=("fcfs", "sjf", "priority"),
                    default="fcfs", help="admission scheduling policy")
    ap.add_argument("--tenants", type=int, default=2,
                    help="number of synthetic tenants for Poisson workloads")
    ap.add_argument("--slo-ttft", type=float, default=8.0,
                    help="TTFT SLO in simulated ticks (goodput threshold)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the percentile metrics summary JSON here")
    ap.add_argument("--mesh", default=None,
                    help="dp,tp — serve tensor-parallel on a (data, model) "
                         "mesh; placeholder CPU devices are forced when the "
                         "host has fewer than dp*tp (CPU-CI friendly)")
    # Fault injection / SLO-aware recovery (repro.serving.faults).
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="per-tick fault probability; enables seeded "
                         "injection into the served weights")
    ap.add_argument("--fault-kinds", default="stuck_col,scale_drift,"
                                             "shard_drop",
                    help="comma-separated subset of "
                         "stuck_col/scale_drift/shard_drop")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic fault trace")
    ap.add_argument("--no-recovery", action="store_true",
                    help="inject but do not detect/repair (degraded-mode "
                         "baseline for the goodput comparison)")
    ap.add_argument("--detect-every", type=int, default=4,
                    help="fingerprint-probe cadence in engine ticks")
    # Paged KV pool + overload robustness (repro.serving.pages).
    ap.add_argument("--paged", action="store_true",
                    help="serve from a paged KV pool (fixed pages aligned "
                         "to the ABFP tile, slot->page-table indirection, "
                         "copy-on-write prefix sharing) instead of "
                         "per-slot max_len strips")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (default: the quant tile "
                         "width, or min(16, max_len) in float mode)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="total pages in the shared pool (default: "
                         "capacity * ceil(max_len / page_size) — the "
                         "unpaged footprint)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request prefix page sharing")
    ap.add_argument("--no-preemption", action="store_true",
                    help="disable evict-to-pool preemption under page "
                         "saturation (victims then wait instead)")
    ap.add_argument("--queue-watermark", type=int, default=None,
                    help="shed newly arrived requests once the arrived "
                         "queue depth reaches this (backpressure; shed "
                         "requests carry a retry_after hint)")
    ap.add_argument("--page-watermarks", default="0.85,0.5",
                    help="hi,lo pool-pressure fractions: degraded mode "
                         "enters at hi and exits at lo (hysteresis)")
    ap.add_argument("--degraded-max-new", type=int, default=None,
                    help="cap max_new_tokens for admissions made while "
                         "degraded (graceful degradation)")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="max pool pages a single tenant may hold "
                         "(projected footprint; noisy-neighbor isolation)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in ticks after arrival; "
                         "expired requests are cancelled and counted "
                         "timed_out")
    # Overlapped wall-clock serving (repro.serving.stream).
    ap.add_argument("--wall-clock", action="store_true",
                    help="drive the engine on time.perf_counter instead of "
                         "the simulated tick clock (latencies/SLOs are then "
                         "in SECONDS)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped dispatch pipeline: sample on device, "
                         "keep tokens unfetched, dispatch tick N+1 before "
                         "tick N's transfer resolves, deliver tokens from a "
                         "background worker; implies --wall-clock")
    ap.add_argument("--inflight", type=int, default=4,
                    help="dispatch-ahead depth for --overlap (bound on "
                         "submitted-but-undelivered passes)")
    args = ap.parse_args()
    if args.overlap:
        args.wall_clock = True

    mesh_shape = parse_mesh(args.mesh)
    mesh = None
    if mesh_shape is not None:
        force_host_devices(mesh_shape[0] * mesh_shape[1])
        if len(jax.devices()) < mesh_shape[0] * mesh_shape[1]:
            raise SystemExit(
                f"--mesh {args.mesh}: needs {mesh_shape[0] * mesh_shape[1]} "
                f"devices but jax was already initialized with "
                f"{len(jax.devices())}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count yourself")
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))

    archs = resolve_archs(args)
    built = {}
    for a in archs:
        cfg = smoke_config(a) if args.reduced else get_config(a)
        if args.fused:
            # The fused decode kernels attend over the int8 quantized KV
            # cache; --fused therefore serves with kv_quant on.
            cfg = dataclasses.replace(cfg, kv_quant=True)
        built[a] = (init_params(jax.random.PRNGKey(args.seed), cfg), cfg)
    mcfg = built[archs[0]][1]
    params = built[archs[0]][0]
    mode = {"float": "float", "abfp": "abfp_ref",
            "abfp-kernel": "abfp_kernel",
            "abfp-packed": "abfp_packed"}[args.quant]
    if args.fused:
        # --fused is an ABFP serving mode; with the (default) float quant
        # it upgrades to the packed config, otherwise it refines whatever
        # ABFP variant was asked for.
        mode = "abfp_fused"
        args.quant = "abfp-fused"
    quant = (QuantConfig(mode=mode, tile_width=args.tile,
                         gain=args.gain, noise_lsb=0.5)
             if mode != "float" else QuantConfig(mode="float"))

    if args.archs is not None:
        if args.fault_rate is not None:
            raise SystemExit("[serve] --archs (fleet mode) does not "
                             "compose with fault injection flags yet")
        serve_fleet(built, quant, mesh, args)
        return

    mesh_note = (f", mesh=({mesh_shape[0]}x{mesh_shape[1]} data x model)"
                 if mesh is not None else "")
    print(f"[serve] {args.arch}: {param_count(params)/1e6:.1f}M params, "
          f"quant={args.quant}, policy={args.policy}{mesh_note}")
    faults = None
    if args.fault_rate is not None:
        faults = FaultConfig(
            rate=args.fault_rate,
            kinds=tuple(k for k in args.fault_kinds.split(",") if k),
            seed=args.fault_seed)
        print(f"[serve] fault injection: rate={args.fault_rate}/tick, "
              f"kinds={args.fault_kinds}, seed={args.fault_seed}, "
              f"recovery={'off' if args.no_recovery else 'on'}")
    try:
        wm_hi, wm_lo = (float(v) for v in args.page_watermarks.split(","))
    except ValueError:
        raise SystemExit(f"--page-watermarks expects 'hi,lo' "
                         f"(got {args.page_watermarks!r})")
    if args.paged:
        print(f"[serve] paged KV pool: page_size="
              f"{args.page_size or 'auto'}, pool_pages="
              f"{args.pool_pages or 'auto'}, prefix_cache="
              f"{not args.no_prefix_cache}, preemption="
              f"{not args.no_preemption}, watermarks=({wm_hi}, {wm_lo})")
    if args.wall_clock:
        unit = "s"
        print(f"[serve] wall clock: overlap="
              f"{'on' if args.overlap else 'off (blocking)'}"
              + (f", inflight={args.inflight}" if args.overlap else ""))
    else:
        unit = "ticks"
    eng = ServingEngine(params, mcfg, capacity=args.capacity,
                        max_len=args.max_len, quant=quant, seed=args.seed,
                        chunked=not args.no_chunked,
                        policy=args.policy,
                        prefill_chunks=tuple(
                            int(c) for c in args.prefill_chunks.split(",")),
                        mesh=mesh,
                        faults=faults,
                        recovery=not args.no_recovery,
                        detect_every=args.detect_every,
                        paged=args.paged,
                        page_size=args.page_size,
                        pool_pages=args.pool_pages,
                        prefix_cache=not args.no_prefix_cache,
                        preemption=(False if args.no_preemption else None),
                        queue_watermark=args.queue_watermark,
                        page_watermarks=(wm_hi, wm_lo),
                        degraded_max_new=args.degraded_max_new,
                        tenant_quota=args.tenant_quota,
                        clock=time.perf_counter if args.wall_clock else None,
                        overlap=args.overlap,
                        inflight=args.inflight)
    if args.wall_clock:
        eng.warmup()        # no compile inside the measured serve window
    rng = np.random.default_rng(args.seed)

    open_loop = args.arrival_rate is not None or args.trace is not None
    if open_loop:
        reqs = (trace_workload(mcfg, args, rng) if args.trace
                else poisson_workload(mcfg, args, rng))
        if args.wall_clock:
            # Workload arrivals are relative offsets; the wall clock reads
            # an arbitrary epoch, so rebase them onto "now".
            base = time.perf_counter()
            for r in reqs:
                r.arrival_time = base + (r.arrival_time or 0.0)
        if args.deadline is not None:
            for r in reqs:
                r.deadline = (r.arrival_time or 0.0) + args.deadline
        for r in reqs:
            eng.submit(r)
        span = (max(r.arrival_time for r in reqs)
                - min(r.arrival_time for r in reqs)) if reqs else 0.0
        print(f"[serve] open-loop: {len(reqs)} requests arriving over "
              f"{span:.1f} {unit}, {args.tenants} tenants")
        t0 = time.time()
        done = eng.drain()
        dt = time.time() - t0
    else:
        reqs = [Request(uid=i,
                        prompt=rng.integers(1, mcfg.vocab_size,
                                            args.prompt_len).tolist(),
                        max_new_tokens=args.max_new,
                        temperature=args.temperature)
                for i in range(args.requests)]
        t0 = time.time()
        done = eng.run(reqs)
        dt = time.time() - t0

    tokens = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s, {eng.ticks} ticks)")

    s = eng.metrics.summary()
    ttft, tpot, e2e = s["ttft"], s["tpot"], s["e2e"]

    def fmt(d, key):
        v = d[key]
        return "-" if v is None else f"{v:.2f}"

    print(f"[serve] TTFT p50 {fmt(ttft, 'p50')} / p99 {fmt(ttft, 'p99')} "
          f"{unit} | TPOT p50 {fmt(tpot, 'p50')} / p99 {fmt(tpot, 'p99')} "
          f"{unit} | E2E p50 {fmt(e2e, 'p50')} / p99 {fmt(e2e, 'p99')} "
          f"{unit}")
    good = eng.metrics.goodput(args.slo_ttft)
    util = s["utilization"]["mean"]
    print(f"[serve] goodput {good if good is None else round(good, 3)} "
          f"req/{unit.rstrip('s') or 's'} (TTFT<={args.slo_ttft}), "
          f"slot utilization "
          f"{'-' if util is None else f'{util:.0%}'}, max queue depth "
          f"{s['queue_depth']['max']}")
    if args.wall_clock:
        tu = s["tick_utilization"]
        tv = tu["value"]
        print(f"[serve] tick utilization "
              f"{'-' if tv is None else f'{tv:.1%}'} "
              f"(device busy {tu['device_busy_s']:.2f}s of "
              f"{tu['active_s']:.2f}s active)")
        eng.close()
    req_s = s["requests"]
    if args.fault_rate is not None or args.deadline is not None:
        f = s["faults"]
        cons = eng.metrics.conservation()
        print(f"[serve] faults: {f['injected']} injected "
              f"({f['injected_stuck_col']} stuck_col, "
              f"{f['injected_scale_drift']} scale_drift, "
              f"{f['injected_shard_drop']} shard_drop), "
              f"{f['detected']} detected, {f['cols_remapped']} cols "
              f"remapped, {f['tiles_requantized']} tiles requantized, "
              f"{f['reshards']} reshards")
        print(f"[serve] timed_out {req_s['timed_out']}, requeued "
              f"{req_s['requeued']}, corrupted {req_s['corrupted']}, "
              f"conservation_ok {cons['ok']}")
    if args.paged:
        pool = s["pool"]
        cons = eng.metrics.conservation()
        print(f"[serve] pool: pressure mean {pool['pressure_mean']:.2f} / "
              f"max {pool['pressure_max']:.2f}, prefix hits "
              f"{pool['prefix_hits']}, cow copies {pool['cow_copies']}, "
              f"degraded ticks {pool['degraded_ticks']}")
        print(f"[serve] overload: shed {req_s['shed']}, preempted "
              f"{req_s['preempted']}, resumed {req_s['resumed']}, "
              f"preempt_ok {cons['preempt_ok']}")
    if args.metrics_out:
        eng.metrics.to_json(args.metrics_out, policy=args.policy,
                            quant=args.quant,
                            slo_ttft=args.slo_ttft,
                            goodput_per_tick=good)
        print(f"[serve] wrote {args.metrics_out}")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")


if __name__ == "__main__":
    main()
