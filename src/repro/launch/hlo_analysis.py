"""Loop-aware HLO analysis: FLOPs, HBM bytes, and collective traffic.

``compiled.cost_analysis()`` counts every computation ONCE — a while-loop
body (every ``lax.scan``: layers, microbatches, attention chunks, ABFP
tiles) is under-counted by its trip count, which makes the naive numbers off
by 1-2 orders of magnitude for scanned models.  This module re-derives the
costs from ``compiled.as_text()`` with execution-count propagation:

  1. parse computations + a per-computation symbol table of result shapes;
  2. build the call graph: ``while`` (body/condition x known_trip_count from
     backend_config), ``fusion``/``call``/``to_apply`` (x1 per call site);
  3. propagate execution counts from ENTRY;
  4. per computation, count
       * dot FLOPs: 2 * prod(result_dims) * contraction_size,
       * HBM bytes: result + operand bytes of top-level ops (fusion bodies
         are NOT traversed for bytes — the fused region reads/writes only at
         its boundary, which is the call site's operands/result),
       * collective wire bytes (ring-algorithm message sizes);
  5. totals = sum(per-computation cost * execution count).

Elementwise FLOPs are ignored (dots dominate the models here); bytes are an
upper-ish approximation of HBM traffic (post-fusion HLO, no register reuse
model).  Both caveats are noted in EXPERIMENTS.md.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# "%name = <shape-or-tuple> opname(operands...)..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|[\w\d]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*->")
_SHAPE_RE = re.compile(r"([\w\d]+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_SKIP_BYTES_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "copy-start", "copy-done",
}


def _shape_dims(shape_str: str):
    """All (dtype, dims) found in a shape string (tuples yield several)."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dtype, d))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _replica_group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota form
    if m:
        return int(m.group(2))
    return default


class _Computation:
    def __init__(self, name):
        self.name = name
        self.shapes: dict = {}          # instr name -> shape string
        self.dot_flops = 0.0
        self.hbm_bytes = 0.0          # fusion-optimistic (major ops)
        self.hbm_pess = 0.0           # every non-trivial op's operands+result
        self.collectives: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
        self.calls: list = []           # (callee, multiplier)


def _parse(hlo_text: str, default_group: int):
    comps: dict = {}
    cur: _Computation | None = None
    pending_instr: list = []

    def flush_instr(comp, line):
        m = _INSTR_RE.match(line)
        if not m:
            return
        name, shape_str, op = m.groups()
        comp.shapes[name] = shape_str

        # --- call graph edges -------------------------------------------------
        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            bm = re.search(r"body=%([\w\.\-]+)", line)
            cm = re.search(r"condition=%([\w\.\-]+)", line)
            if bm:
                comp.calls.append((bm.group(1), trip))
            if cm:
                comp.calls.append((cm.group(1), trip + 1))
        else:
            for key in ("calls", "to_apply", "body", "condition",
                        "branch_computations"):
                for mm in re.finditer(key + r"=\{?%([\w\.\-]+)", line):
                    comp.calls.append((mm.group(1), 1))

        # --- dot flops --------------------------------------------------------
        if op in ("dot", "dot-general") or op.startswith("dot"):
            res = _shape_dims(shape_str)
            res_elems = 1
            for _, dims in res[:1]:
                for d in dims:
                    res_elems *= d
            # contraction size from lhs operand shape x contracting dims
            lhs_m = _OPERAND_RE.search(line[line.index("(") + 1:]) \
                if "(" in line else None
            contract = 1
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if lhs_m and cd and cd.group(1):
                lhs_shape = comp.shapes.get(lhs_m.group(1))
                if lhs_shape:
                    dims = _shape_dims(lhs_shape)
                    if dims:
                        lhs_dims = dims[0][1]
                        for i in cd.group(1).split(","):
                            i = int(i)
                            if i < len(lhs_dims):
                                contract *= lhs_dims[i]
            comp.dot_flops += 2.0 * res_elems * contract

        # --- collectives ------------------------------------------------------
        base = None
        for kind in COLLECTIVES:
            if op == kind or op.startswith(kind + "-start") or \
                    op.startswith(kind + "."):
                base = kind
                break
        if base is not None:
            size = _shape_bytes(shape_str)
            g = _replica_group_size(line, default_group)
            ring = (g - 1) / g if g > 1 else 0.0
            if base == "all-reduce":
                wire = 2 * size * ring
            elif base == "collective-permute":
                wire = size
            else:
                wire = size * ring
            comp.collectives[base]["count"] += 1
            comp.collectives[base]["bytes"] += int(wire)

        # --- bytes ------------------------------------------------------------
        def operand_bytes():
            if "(" not in line:
                return 0
            args = line[line.index("(") + 1: line.find(")", line.index("("))]
            return sum(_shape_bytes(comp.shapes.get(om.group(1), ""))
                       for om in _OPERAND_RE.finditer(args))

        if op not in _SKIP_BYTES_OPS:
            comp.hbm_pess += _shape_bytes(shape_str) + operand_bytes()

        # Fusion-optimistic ("major-op") model: on TPU, elementwise /
        # broadcast / convert / transpose chains fuse into the neighbouring
        # major op, so HBM traffic ~= traffic of the major data movers only.
        res_b = _shape_bytes(shape_str)
        if op in ("dot", "convolution", "reduce", "reduce-window", "sort",
                  "custom-call", "fusion", "cholesky", "triangular-solve") \
                or op.startswith("dot") or base is not None:
            comp.hbm_bytes += res_b + operand_bytes()
        elif op in ("dynamic-slice", "gather", "concatenate", "pad",
                    "slice", "reverse"):
            comp.hbm_bytes += 2 * res_b            # read region + write result
        elif op in ("dynamic-update-slice", "scatter"):
            # in-place region update: read+write of the UPDATE sized region
            # (operand 1), not the whole buffer.
            upd = 0
            if "(" in line:
                args = line[line.index("(") + 1:
                            line.find(")", line.index("("))]
                names = [m.group(1) for m in _OPERAND_RE.finditer(args)]
                if len(names) >= 2:
                    upd = _shape_bytes(comp.shapes.get(names[1], ""))
            comp.hbm_bytes += 2 * upd

    entry = None
    lines = hlo_text.splitlines()
    i = 0
    while i < len(lines):
        raw = lines[i]
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            i += 1
            continue
        if cur is not None:
            s = line.strip()
            if s == "}":
                cur = None
            elif s.startswith("%") or s.startswith("ROOT"):
                # join continuation lines (instr can wrap)
                full = s
                while i + 1 < len(lines) and not (
                        lines[i + 1].strip().startswith("%")
                        or lines[i + 1].strip().startswith("ROOT")
                        or lines[i + 1].strip() == "}"):
                    i += 1
                    full += " " + lines[i].strip()
                flush_instr(cur, full)
        i += 1
    return comps, entry


def _propagate_counts(comps: dict, entry: str) -> dict:
    counts: dict = defaultdict(float)
    counts[entry] = 1.0
    # Call graph is a DAG (HLO has no recursion): fixpoint in a few passes.
    for _ in range(len(comps) + 2):
        new = defaultdict(float)
        new[entry] = 1.0
        for name, comp in comps.items():
            k = counts[name] if name in counts else 0.0
            if k == 0.0:
                continue
            for callee, mult in comp.calls:
                if callee in comps:
                    new[callee] += k * mult
        if dict(new) == dict(counts):
            break
        counts = new
    return counts


_FUSION_BODY_RE = re.compile(r"fused|wrapped")


def loop_aware_costs(hlo_text: str, default_group: int = 2) -> dict:
    """Execution-count-corrected {flops, hbm_bytes, collectives} totals."""
    comps, entry = _parse(hlo_text, default_group)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "hbm_bytes_pessimistic": 0.0,
                "collectives": {"total": {"count": 0, "bytes": 0}}}
    counts = _propagate_counts(comps, entry)

    flops = 0.0
    hbm = 0.0
    hbm_pess = 0.0
    colls: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for name, comp in comps.items():
        k = counts.get(name, 0.0)
        if k == 0.0:
            continue
        flops += comp.dot_flops * k
        # bytes: skip fusion/wrapped computation BODIES (boundary counted at
        # the call site); while bodies and entry are real.
        if not _FUSION_BODY_RE.search(name):
            hbm += comp.hbm_bytes * k
            hbm_pess += comp.hbm_pess * k
        for kind, v in comp.collectives.items():
            colls[kind]["count"] += int(v["count"] * k)
            colls[kind]["bytes"] += int(v["bytes"] * k)

    total = {"count": sum(v["count"] for v in colls.values()),
             "bytes": sum(v["bytes"] for v in colls.values())}
    out_colls = {k: dict(v) for k, v in colls.items()}
    out_colls["total"] = total
    return {"flops": flops, "hbm_bytes": hbm, "hbm_bytes_pessimistic": hbm_pess,
            "collectives": out_colls}


# Backwards-compatible entry point used by tests: collective stats only.
def collective_stats(hlo_text: str, default_group: int = 2) -> dict:
    return loop_aware_costs(hlo_text, default_group)["collectives"]


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int, *, peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> dict:
    """The three roofline terms in seconds (per-device program costs)."""
    compute_s = flops / peak_flops
    memory_s = hbm_bytes / hbm_bw
    collective_s = collective_bytes / ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["bottleneck"] = max(
        terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms
