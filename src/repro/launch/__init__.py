"""repro.launch — mesh, dry-run, drivers.  NOTE: importing dryrun sets
XLA_FLAGS; import it only in dry-run processes."""
