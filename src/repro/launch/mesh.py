"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 placeholder devices *before* first jax init, and smoke
tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_PER_CHIP = 16 * 2**30         # 16 GiB
