"""Optimizers and schedules — the paper's finetuning recipes (Sec. V-B).

AdamW (lr 1e-6, x0.3/epoch decay — ResNet50 recipe) and SGD with momentum
0.728 / weight-decay 5e-4 under a cosine one-cycle schedule (SSD recipe),
plus mixed-precision plumbing: bf16 params, f32 master copies and moments.

ZeRO-1 sharding of the optimizer state lives in ``repro.distributed``; these
update rules are pure pytree math and shard transparently.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
Pytree = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def exponential_decay(base_lr: float, decay: float, steps_per_epoch: int):
    """lr * decay^epoch (the paper's ResNet50 recipe: decay 0.3 per epoch)."""
    def fn(step):
        epoch = step // steps_per_epoch
        return base_lr * decay ** epoch
    return fn


def cosine_one_cycle(base_lr: float, total_steps: int, warmup_frac: float = 0.1):
    """One-cycle cosine with linear warmup (the paper's SSD recipe)."""
    warm = max(1, int(total_steps * warmup_frac))

    def fn(step):
        step = jnp.minimum(step, total_steps)
        lr_warm = base_lr * step / warm
        t = jnp.clip((step - warm) / jnp.maximum(total_steps - warm, 1), 0, 1)
        lr_cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warm, lr_warm, lr_cos)
    return fn


def constant(base_lr: float):
    return lambda step: jnp.float32(base_lr)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: Array
    mu: Pytree          # f32 first moment
    nu: Pytree          # f32 second moment
    master: Pytree      # f32 master weights (mixed precision)


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable[[Array], Array]
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0

    def init(self, params: Pytree) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        # copy=True: with f32 params, astype would alias the param buffer and
        # break donation (same buffer donated twice).
        master = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros), master)

    def update(self, grads: Pytree, state: AdamWState, params: Pytree):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads = clip_by_global_norm(grads, self.grad_clip_norm)
        step = state.step + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(master, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            u = u + self.weight_decay * master
            return master - lr * u

        master = jax.tree.map(upd, state.master, mu, nu)
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, AdamWState(step, mu, nu, master)


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------


class SGDState(NamedTuple):
    step: Array
    velocity: Pytree
    master: Pytree


@dataclasses.dataclass(frozen=True)
class SGD:
    schedule: Callable[[Array], Array]
    momentum: float = 0.728          # the paper's SSD-ResNet34 value
    weight_decay: float = 5e-4
    grad_clip_norm: Optional[float] = None

    def init(self, params: Pytree) -> SGDState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        return SGDState(jnp.zeros((), jnp.int32), zeros, master)

    def update(self, grads: Pytree, state: SGDState, params: Pytree):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads = clip_by_global_norm(grads, self.grad_clip_norm)
        step = state.step + 1
        lr = self.schedule(step)

        def vel(v, g, m):
            return self.momentum * v + g + self.weight_decay * m

        velocity = jax.tree.map(vel, state.velocity, grads, state.master)
        master = jax.tree.map(lambda m, v: m - lr * v, state.master, velocity)
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, SGDState(step, velocity, master)


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads: Pytree, max_norm: Optional[float]) -> Pytree:
    if max_norm is None:
        return grads
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def global_norm(tree: Pytree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
