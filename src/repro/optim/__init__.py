"""repro.optim — AdamW / SGD + schedules (the paper's finetuning recipes)."""
from repro.optim.optimizers import (  # noqa: F401
    SGD, AdamW, AdamWState, SGDState, clip_by_global_norm,
    constant, cosine_one_cycle, exponential_decay, global_norm)
