"""repro.core — the paper's contribution: ABFP numerics, DNF, energy model."""

from repro.core.abfp import (  # noqa: F401
    FLOAT,
    PackedWeight,
    QuantConfig,
    abfp_matmul,
    abfp_matmul_ste,
    adc,
    ams_noise,
    dequantize_packed,
    digital_bfp_matmul,
    encode_codes,
    pack_abfp_weight,
    pad_to_tiles,
    quant_delta,
    quant_levels,
    quantize,
    quantize_input_tiles,
    quantize_ste,
    quantize_weight_tiles,
    safe_scale,
    tile_scales,
)
from repro.core.dnf import (  # noqa: F401
    NoiseHistogram,
    capture_differential_noise,
    inject,
    select_layers_by_std,
)
from repro.core import energy  # noqa: F401
