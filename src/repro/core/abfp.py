"""Adaptive Block Floating-Point (ABFP) numerics — the paper's core contribution.

Implements, in pure JAX:
  * the symmetric round-half-even quantizer Q(v; delta, tau)        (Eq. 1)
  * per-tile adaptive scales s = max|v| stored in BFLOAT16          (Sec. III-A)
  * the tiled ABFP matmul with per-(row, tile) weight scales and
    per-(sample, tile) activation scales                            (Eq. 2-4)
  * gain G applied before the ADC quantizer, divided out after      (Eq. 5-6)
  * the AMS additive-uniform ADC noise model                        (Eq. 7)
  * a straight-through-estimator wrapper for QAT                    (Sec. IV-A, Eq. 8)

Scales are computed at runtime ("adaptive"), rounded to ``scale_dtype``
(BFLOAT16 by default, matching the paper's storage format), and the partial
dot-product outputs are accumulated in FLOAT32 before the final cast to
BFLOAT16 (Sec. III: "the final sum is accumulated in FLOAT32").
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of the simulated AMS device.

    Hashable / frozen so it can be closed over by ``jax.jit`` as a static
    argument.  ``mode`` selects the execution path used by ``repro.kernels.ops``:

      * ``"float"``       — plain (b)f16/f32 matmul, no ABFP (the FLOAT32 baseline)
      * ``"abfp_ref"``    — pure-jnp scan implementation (this module)
      * ``"abfp_kernel"`` — fused Pallas TPU kernel (``repro.kernels``)
      * ``"abfp_packed"`` — packed Pallas kernel over pre-quantized weights
        (``pack_abfp_weight``): the quantize-once serving path
      * ``"abfp_fused"``  — the packed path plus (a) per-tile adaptive ADC
        gains baked into the packed weights (``adaptive_tile_gains``; the
        paper's amplification knob, chosen per tile from the programmed
        codes, bounded by ``gain``) and (b) the fused Pallas decode-step
        kernels (``repro.kernels.abfp_decode_fused``: one QKV launch + one
        quantized-KV attention kernel) on the single-token decode hot path.
        At ``gain=1.0`` every per-tile gain is 1 and the path is
        bit-identical to ``"abfp_packed"``.
    """

    tile_width: int = 128          # n — vector length sharing one scale
    bits_w: int = 8                # b_W
    bits_x: int = 8                # b_X
    bits_y: int = 8                # b_Y (ADC output bits)
    gain: float = 1.0              # G >= 1, powers of two in the paper
    noise_lsb: float = 0.0         # ADC noise half-width in output LSBs
                                   # (paper: 0.5 => E ~ U(-n*dY/2, +n*dY/2))
    mode: str = "abfp_ref"
    scale_dtype: Any = jnp.bfloat16
    out_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32
    quantize_attention: bool = False  # paper quantizes weight-activation
                                      # products only; attn score/value
                                      # contractions optional.
    scale_percentile: Optional[float] = None
    # Paper Sec. VI future work: use a measured percentile of |v| instead of
    # max|v| for the adaptive scale (Wu et al. [29]) — clips outliers into
    # the tau=1 clamp, buying resolution for the bulk of the distribution.
    # None = the paper's max-abs scaling.

    def replace(self, **kw) -> "QuantConfig":
        """Return a copy with the given fields replaced."""
        return dataclasses.replace(self, **kw)

    @property
    def delta_w(self) -> float:
        """Weight quantization bin size, delta(bits_w)."""
        return quant_delta(self.bits_w)

    @property
    def delta_x(self) -> float:
        """Activation quantization bin size, delta(bits_x)."""
        return quant_delta(self.bits_x)

    @property
    def delta_y(self) -> float:
        """ADC output quantization bin size, delta(bits_y)."""
        return quant_delta(self.bits_y)

    @property
    def adc_code_scale(self) -> float:
        """Maps exact integer partial products to ADC code units.

        The analog MAC computes the dot product of the integer operand codes
        exactly; in code units the ADC (Eq. 5/7) is

            y_code = clamp(round(p_int * adc_code_scale + E_lsb), +-L_y)

        with adc_code_scale = G * d_X * d_W / (n * d_Y) and E_lsb the noise in
        output LSBs.  Computed in float64 here so every implementation
        (scan / einsum oracle / Pallas kernel) multiplies by the *same* f32
        constant and resolves round-half-even ties identically.
        """
        return float(
            self.gain * self.delta_x * self.delta_w
            / (self.tile_width * self.delta_y)
        )

    @property
    def adc_base_scale(self) -> float:
        """``adc_code_scale`` at G = 1: d_X * d_W / (n * d_Y).

        The per-tile-gain path (``PackedWeight.gains``) multiplies this base
        by each tile's own G_t instead of the global ``gain``; computed in
        float64 for the same tie-resolution guarantee as ``adc_code_scale``.
        ``f32(adc_base_scale) * 1.0 == f32(adc_code_scale)`` when
        ``gain == 1.0``, which is what makes the all-ones-gains path
        bit-identical to the scalar-gain path.
        """
        return float(
            self.delta_x * self.delta_w / (self.tile_width * self.delta_y)
        )

    @property
    def bin_y(self) -> float:
        """ADC output bin (one LSB): n * delta_y."""
        return float(self.tile_width * self.delta_y)


FLOAT = QuantConfig(mode="float")


def quant_delta(bits: int) -> float:
    """delta_b = 1 / (2**(b-1) - 1): bin size of symmetric signed quantization."""
    return 1.0 / (2 ** (bits - 1) - 1)


def quant_levels(bits: int) -> int:
    """L_b = 2**(b-1) - 1: largest integer code (symmetric signed)."""
    return 2 ** (bits - 1) - 1


# ---------------------------------------------------------------------------
# Eq. 1 — the quantizer
# ---------------------------------------------------------------------------


def quantize(v: Array, delta, tau) -> Array:
    """Q(v; delta, tau) = clamp(round_half_even(v / delta) * delta; +-tau).

    ``jnp.round`` implements round-half-to-even, matching the paper.
    """
    return jnp.clip(jnp.round(v / delta) * delta, -tau, tau)


# ---------------------------------------------------------------------------
# Per-tile adaptive scales
# ---------------------------------------------------------------------------


def tile_scales(v_tiles: Array, scale_dtype=jnp.bfloat16,
                percentile: "Optional[float]" = None) -> Array:
    """max|v| (or a |v| percentile) over the last axis, rounded to the scale
    storage dtype.

    ``v_tiles``: (..., n).  Returns (...,) in f32 (value already representable
    in ``scale_dtype``).  A zero tile gets scale 0 here; callers use
    ``safe_scale`` to avoid 0/0.

    ``percentile`` (paper Sec. VI future work / Wu et al. [29]): scale by the
    p-th percentile of |v| instead of the max — outliers saturate into the
    tau=1 clamp, improving resolution for the rest of the tile.
    """
    a = jnp.abs(v_tiles.astype(jnp.float32))
    if percentile is None or percentile >= 100.0:
        s = jnp.max(a, axis=-1)
    else:
        s = jnp.percentile(a, percentile, axis=-1)
    # Round to bf16 storage.  bf16(max) may round *down*, pushing |v|/s
    # slightly above 1; the tau=1 clamp in Eq. 2 absorbs this, exactly as the
    # hardware's DAC saturation would.
    return s.astype(scale_dtype).astype(jnp.float32)


def safe_scale(s: Array) -> Array:
    """Replace zero scales with 1.0 so all-zero tiles divide to exact 0."""
    return jnp.where(s == 0.0, 1.0, s)


def pad_to_tiles(v: Array, n: int, axis: int) -> Array:
    """Zero-pad ``axis`` of v up to a multiple of the tile width n."""
    k = v.shape[axis]
    rem = (-k) % n
    if rem == 0:
        return v
    pads = [(0, 0)] * v.ndim
    pads[axis] = (0, rem)
    return jnp.pad(v, pads)


# ---------------------------------------------------------------------------
# Eq. 7 — AMS (ADC) noise
# ---------------------------------------------------------------------------


def ams_noise(key: Array, shape, cfg: QuantConfig) -> Array:
    """Additive uniform ADC noise E ~ U(-w, +w), w = noise_lsb * (n * delta_y).

    Paper Sec. III-C: the error is one output-quantization bin wide
    (noise_lsb = 0.5 => +-0.5 LSB, Var = (n*delta_y)^2 / 12) and independent
    of the operand values.
    """
    lsb = cfg.tile_width * cfg.delta_y
    half_width = cfg.noise_lsb * lsb
    return jax.random.uniform(
        key, shape, dtype=jnp.float32, minval=-half_width, maxval=half_width
    )


# ---------------------------------------------------------------------------
# Weight pre-quantization (Sec. III-A: weights are converted to ABFP once)
# ---------------------------------------------------------------------------


def code_dtype(bits: int):
    """Storage dtype for integer codes: bf16 when exact (L <= 256, i.e.
    bits <= 9 — bf16's 8-bit mantissa represents those integers exactly), so
    the tile dot runs at the MXU's bf16 rate instead of ~1/8 rate f32 (perf
    iteration, EXPERIMENTS.md §Perf); f32 above that.

    REPRO_ABFP_F32_CODES=1 forces f32 codes (the pre-optimization baseline;
    used by the §Perf before/after measurement).
    """
    import os
    if os.environ.get("REPRO_ABFP_F32_CODES"):
        return jnp.float32
    return jnp.bfloat16 if quant_levels(bits) <= 256 else jnp.float32


def encode_codes(v_hat: Array, bits: int) -> Array:
    """Normalized values -> integer codes in [-L, L].

    round(v_hat * L) == round(v_hat / delta): the DAC encoding of Eq. 2.
    Integer codes make the tile dot product *exact* under an f32 accumulator
    (|p| <= n*L_x*L_w = 128*127*127 ~ 2^21 < 2^24 at 8 bits), which is both
    what the analog MAC array physically computes and what lets three
    independent implementations resolve ADC round-half-even ties identically.
    Codes are stored in bf16 when exactly representable (bits <= 9).
    """
    lvl = float(quant_levels(bits))
    return jnp.clip(jnp.round(v_hat * lvl), -lvl, lvl).astype(code_dtype(bits))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedWeight:
    """Pre-quantized ABFP weight: quantize once, serve forever.

    The paper's AMS device programs weight tiles into the analog array once
    and then only streams activations; this container is the digital analog.
    ``pack_abfp_weight`` runs the weight side of Eq. 2 (max-abs tile scale,
    bf16-rounded, then round-half-even integer encoding) ahead of time, so
    the serving hot path never touches the original float weights.

    Layout (supports leading batch axes for scan-stacked / MoE params):

      codes : int8     (..., Kp, Np)  integer codes in [-L_w, +L_w], row
                                      ``t*n + i`` is element ``i`` of K-tile
                                      ``t`` (i.e. the natural (K, N) layout,
                                      zero-padded to Kp = ceil(K/n)*n rows
                                      and Np = ceil(N/128)*128 lane-aligned
                                      columns, so the serving hot path never
                                      re-pads the weight per call)
      scales: bfloat16 (..., T, Np)   per-(tile, out-column) scales, T=Kp/n
                                      (``cfg.scale_dtype``; bf16 by default)
      gains : float32  (..., T) or None — OPTIONAL per-tile ADC gains
                                      (power-of-two, in [1, cfg.gain]; the
                                      paper's amplification knob, adaptive
                                      per tile).  When present they REPLACE
                                      the scalar ``cfg.gain`` in the ADC:
                                      ``y_t = clamp(round(p_t * base * G_t))``
                                      amplified before output quantization,
                                      then divided out (``/ G_t``) in the
                                      Eq. 6 accumulation.  ``None`` (the
                                      default) keeps the scalar-gain path
                                      byte-for-byte unchanged.

    Static metadata (pytree aux, hashable):

      k          — the original, un-padded K (rows beyond k are zero codes
                   with zero scales: they contribute exactly 0)
      n_cols     — the original, un-padded N (columns beyond n_cols are
                   zero codes with zero scales; sliced off the output)
      tile_width — n, the ABFP tile width the codes were packed for
      bits_w     — b_W used at pack time (int8 requires bits_w <= 8)

    The represented value lattice is ``codes * delta_w * scales`` — exactly
    the lattice ``quantize_weight_tiles`` / the Pallas kernel derive at run
    time, so packed and unpacked execution are bit-identical.
    """

    codes: Array
    scales: Array
    k: int
    n_cols: int
    tile_width: int
    bits_w: int
    gains: Optional[Array] = None

    def tree_flatten(self):
        """Flatten to (codes, scales, gains) children + hashable aux.

        A ``None`` gains child flattens to an empty subtree, so packed trees
        without gains keep their historical structure (two leaves per
        weight) and every existing tree_map / device_put zip is unchanged.
        """
        return (self.codes, self.scales, self.gains), (
            self.k, self.n_cols, self.tile_width, self.bits_w)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from ``tree_flatten`` output."""
        codes, scales, gains = children
        return cls(codes, scales, *aux, gains=gains)

    @property
    def kp(self) -> int:
        """K padded up to whole tiles (the codes' row count)."""
        return self.codes.shape[-2]

    @property
    def n_out(self) -> int:
        """Un-padded output-column count (alias of ``n_cols``)."""
        return self.n_cols

    @property
    def n_padded(self) -> int:
        """N padded up to whole 128-lane blocks (the codes' column count)."""
        return self.codes.shape[-1]

    @property
    def num_tiles(self) -> int:
        """Number of K-tiles, T = Kp / tile_width."""
        return self.scales.shape[-2]

    @property
    def shape(self):
        """Logical (un-padded) weight shape, leading batch axes included."""
        return self.codes.shape[:-2] + (self.k, self.n_cols)

    @property
    def ndim(self) -> int:
        """Rank of the codes array (leading batch axes + the (Kp, Np) pair)."""
        return self.codes.ndim

    def __getitem__(self, idx) -> "PackedWeight":
        """Index leading batch axes (e.g. MoE expert selection) — the packed
        analogue of ``params['wi'][ex]``."""
        return PackedWeight(self.codes[idx], self.scales[idx],
                            self.k, self.n_cols, self.tile_width, self.bits_w,
                            gains=None if self.gains is None
                            else self.gains[idx])

    def nbytes(self) -> int:
        """HBM footprint of the packed representation."""
        total = self.codes.size * self.codes.dtype.itemsize \
            + self.scales.size * self.scales.dtype.itemsize
        if self.gains is not None:
            total += self.gains.size * self.gains.dtype.itemsize
        return total


_LANE = 128  # TPU lane width; packed N is pre-aligned to it at pack time.


def pack_abfp_weight(w: Array, cfg: QuantConfig,
                     adaptive_gain: bool = False) -> PackedWeight:
    """Quantize a (..., K, N) weight to ABFP once, for the packed serving path.

    Bit-identical to the quantization the kernel / ``quantize_weight_tiles``
    perform per call: same ``scale_dtype``-rounded max-abs scales, same
    round-half-even integer encoding.  Codes are stored as int8 (requires
    ``bits_w <= 8``; L_w <= 127), halving weight HBM traffic vs bf16 codes
    and quartering it vs f32 weights.  N is zero-padded to the 128-lane
    boundary here, once, so the kernel wrapper never re-pads the weight on
    the hot path (zero columns carry zero scales: exact no-ops).

    ``scale_percentile`` configs are rejected: the Pallas kernels (packed
    and unpacked) implement the paper's max-abs scaling only — percentile
    scaling lives in the ``abfp_ref``/scan path.

    ``adaptive_gain=True`` (the ``mode="abfp_fused"`` packing path)
    additionally derives per-tile ADC gains from the packed codes
    (``adaptive_tile_gains``) and stores them as ``PackedWeight.gains``;
    the codes and scales themselves are unaffected (gain acts at the ADC,
    not on the programmed array).
    """
    if quant_levels(cfg.bits_w) > 127:
        raise ValueError(
            f"pack_abfp_weight stores int8 codes; bits_w={cfg.bits_w} "
            f"(L_w={quant_levels(cfg.bits_w)}) does not fit")
    if cfg.scale_percentile is not None:
        raise ValueError(
            "pack_abfp_weight supports max-abs scales only (the Pallas "
            "kernels do not implement scale_percentile; use mode='abfp_ref')")
    n = cfg.tile_width
    k, n_cols = w.shape[-2], w.shape[-1]
    w = pad_to_tiles(w.astype(jnp.float32), n, axis=-2)
    w = pad_to_tiles(w, _LANE, axis=-1)
    lead = w.shape[:-2]
    kp, npad = w.shape[-2], w.shape[-1]
    t = kp // n
    wt = w.reshape(*lead, t, n, npad)                       # (..., T, n, Np)
    s_w = tile_scales(jnp.moveaxis(wt, -2, -1), cfg.scale_dtype)
    w_hat = wt / safe_scale(s_w)[..., None, :]              # (..., T, n, Np)
    codes = encode_codes(w_hat, cfg.bits_w).astype(jnp.int8)
    pw = PackedWeight(
        codes=codes.reshape(*lead, kp, npad),
        scales=s_w.astype(cfg.scale_dtype),
        k=k, n_cols=n_cols, tile_width=n, bits_w=cfg.bits_w,
    )
    if adaptive_gain:
        pw = dataclasses.replace(pw, gains=adaptive_tile_gains(pw, cfg))
    return pw


def adaptive_tile_gains(pw: PackedWeight, cfg: QuantConfig) -> Array:
    """Per-tile power-of-two ADC gains in [1, cfg.gain] — (..., T) f32.

    The paper's amplification knob: the ADC normalizes every tile dot
    product by the worst case (|p| <= n, full-scale operands on all n
    rows), so a typical tile's output lands orders of magnitude below full
    scale and wastes output LSBs.  Gain G_t amplifies tile t's partial
    product before the b_Y-bit output quantizer and is divided out after
    (Eq. 5-6), recovering log2(G_t) effective output bits — as long as the
    amplified product stays inside the ADC range (the clamp absorbs the
    rare overshoot, exactly like the hardware's saturation).

    "Adaptive" per the ABFP scheme: G_t is chosen from the statistics of
    tile t's *programmed codes*, which are known at pack time.  For
    operands with RMS r_x, r_w the central-limit magnitude of the
    normalized tile dot is ~ sqrt(n) * r_x * r_w / n of full scale; with a
    conservative unit bound for the activation side (|x_hat| <= 1 by
    construction) and a 4-sigma guard, the headroom of tile t is
    ``n / (4 * sqrt(n) * rms(w_hat_t))``.  The gain is the largest power
    of two below both that headroom and the global ``cfg.gain`` budget —
    so ``cfg.gain == 1.0`` yields all-ones gains (the exact scalar path)
    and larger budgets amplify only tiles that can take it.
    """
    lvl_w = float(quant_levels(cfg.bits_w))
    n = pw.tile_width
    lead = pw.codes.shape[:-2]
    w_hat = pw.codes.astype(jnp.float32).reshape(
        *lead, pw.num_tiles, n, pw.n_padded) / lvl_w
    # RMS over the tile's real columns only — zero-padded lanes carry zero
    # scales (exact no-ops) and would otherwise deflate the estimate.
    w_real = w_hat[..., :pw.n_cols]
    rms = jnp.sqrt(jnp.mean(w_real * w_real, axis=(-2, -1)))    # (..., T)
    expected = 4.0 * jnp.sqrt(float(n)) * jnp.maximum(rms, 1e-6) / float(n)
    headroom = 1.0 / expected
    g = jnp.exp2(jnp.floor(jnp.log2(
        jnp.clip(headroom, 1.0, float(cfg.gain)))))
    return g.astype(jnp.float32)


def dequantize_packed(pw: PackedWeight) -> Array:
    """Packed codes + scales -> the quantized-value lattice, (..., k, N) f32.

    ``codes * delta_w * scales`` per Eq. 2; used by the STE backward (the
    gradient sees the values the forward actually multiplied by) and tests.
    """
    n = pw.tile_width
    lead = pw.codes.shape[:-2]
    ct = pw.codes.astype(jnp.float32).reshape(
        *lead, pw.num_tiles, n, pw.n_padded)
    s = pw.scales.astype(jnp.float32)[..., :, None, :]       # (..., T, 1, Np)
    d = jnp.float32(quant_delta(pw.bits_w))
    w = (ct * d * s).reshape(*lead, pw.kp, pw.n_padded)
    return w[..., :pw.k, :pw.n_cols]


def scale_storage_eps(scale_dtype=jnp.bfloat16) -> float:
    """Relative quantum of the scale storage dtype (bf16: 2^-8 ≈ 0.39%).

    The smallest relative change of a stored tile scale that is
    representable — anything below it is storage noise, anything a few
    multiples above it is a REAL change of the programmed array.  Fault
    detection (``serving.faults``) derives its drift tolerance from this:
    the ABFP scale statistics bound how far a healthy tile's fingerprint
    can move without the array having drifted.
    """
    return float(jnp.finfo(scale_dtype).eps) / 2.0


def packed_tile_fingerprint(pw: PackedWeight) -> Array:
    """Per-(tile, col) probe response ``R[t, j] = (sum_i |codes[t, i, j]|)
    * delta_w * scales[t, j]`` — (..., T, Np) f32.

    The digital analogue of a calibration-ramp readout: drive every row of
    tile ``t`` with a full-scale input and read column ``j``'s magnitude.
    The inner |code| sum is exact in f32 (|p| <= n * L_w < 2^24), so for a
    healthy array the fingerprint is bit-stable across reads; a drifted
    scale moves R by exactly the drift factor and a dead column reads 0.
    Cost is one pass over the codes — the cheap per-probe detection path
    (``serving.faults.detect_site``), NOT a model forward.
    """
    n = pw.tile_width
    lead = pw.codes.shape[:-2]
    ct = jnp.abs(pw.codes.astype(jnp.float32)).reshape(
        *lead, pw.num_tiles, n, pw.n_padded)
    code_sum = ct.sum(axis=-2)                              # (..., T, Np)
    d = jnp.float32(quant_delta(pw.bits_w))
    return code_sum * d * pw.scales.astype(jnp.float32)


def packed_output_error_bound(pw: PackedWeight, cfg: QuantConfig) -> Array:
    """Worst-case |y[j]| bound per output column for unit-scale inputs,
    (..., Np) f32.

    Per tile the exact partial product obeys ``|p| * d_X * d_W <=
    d_W * sum_i |codes[t, i, j]|`` when every ``|x_hat_i| <= 1``, i.e. the
    fingerprint is the largest represented response any admissible input
    can draw; ADC rounding plus LSB noise add at most ``(0.5 + noise_lsb)
    * bin_y / G`` per tile (the clamp only shrinks further).  Summed over
    tiles this is a sound envelope: any healthy column's probe response
    sits below it, so a reading ABOVE the bound is unambiguous corruption
    (the converse, a dead column, is caught by the fingerprint zero test
    in ``serving.faults.detect_site``).
    """
    fp = packed_tile_fingerprint(pw)                        # (..., T, Np)
    s = pw.scales.astype(jnp.float32)
    if pw.gains is not None:
        # Per-tile gains divide the per-tile ADC rounding envelope.
        adc_err = ((0.5 + cfg.noise_lsb) * cfg.bin_y
                   / pw.gains.astype(jnp.float32))[..., :, None]
    else:
        adc_err = (0.5 + cfg.noise_lsb) * cfg.bin_y / cfg.gain
    return (fp + s * adc_err).sum(axis=-2)


def quantize_weight_tiles(w: Array, cfg: QuantConfig):
    """Convert a (K, N) weight matrix into ABFP tiles.

    Returns (w_q, s_w):
      w_q: (T, n, N) integer weight codes in [-L_w, +L_w] (f32 storage)
      s_w: (T, N)    per-(tile, output) scales, bf16-rounded, f32 dtype

    The quantized *value* lattice of Eq. 2 is ``w_q * delta_w * s_w``.
    """
    n = cfg.tile_width
    w = pad_to_tiles(w.astype(jnp.float32), n, axis=0)
    kp = w.shape[0]
    t = kp // n
    wt = w.reshape(t, n, w.shape[1])                       # (T, n, N)
    s_w = tile_scales(jnp.moveaxis(wt, 1, -1), cfg.scale_dtype,
                      cfg.scale_percentile)              # (T, N)
    w_hat = wt / safe_scale(s_w)[:, None, :]
    w_q = encode_codes(w_hat, cfg.bits_w)
    return w_q, s_w


def quantize_input_tiles(x: Array, cfg: QuantConfig):
    """Convert (..., K) activations into ABFP tiles.

    Returns (x_q, s_x):
      x_q: (..., T, n) integer activation codes in [-L_x, +L_x] (f32 storage)
      s_x: (..., T)    per-(sample, tile) scales
    """
    n = cfg.tile_width
    x = pad_to_tiles(x.astype(jnp.float32), n, axis=-1)
    t = x.shape[-1] // n
    xt = x.reshape(*x.shape[:-1], t, n)                    # (..., T, n)
    s_x = tile_scales(xt, cfg.scale_dtype, cfg.scale_percentile)  # (..., T)
    x_hat = xt / safe_scale(s_x)[..., None]
    x_q = encode_codes(x_hat, cfg.bits_x)
    return x_q, s_x


def adc(p_codes: Array, cfg: QuantConfig,
        noise_lsb_draw: Optional[Array] = None,
        tile_gain: Optional[Array] = None) -> Array:
    """Eq. 5/7 in code units: the ADC conversion of an exact integer partial
    product.  Returns output codes in [-L_y, +L_y]; the represented value is
    ``codes * bin_y`` (bin_y = n*delta_y, clamp tau_Y = n).

    ``tile_gain`` (a scalar or broadcastable array) replaces the scalar
    ``cfg.gain`` with a per-tile amplification G_t:
    ``y = clamp(round(p * adc_base_scale * G_t + E))`` — the caller divides
    the represented value by the same G_t in the Eq. 6 accumulation.
    """
    if tile_gain is None:
        v = p_codes * jnp.float32(cfg.adc_code_scale)
    else:
        v = p_codes * jnp.float32(cfg.adc_base_scale) * tile_gain
    if noise_lsb_draw is not None:
        v = v + noise_lsb_draw
    lvl = float(quant_levels(cfg.bits_y))
    return jnp.clip(jnp.round(v), -lvl, lvl)


# ---------------------------------------------------------------------------
# Eq. 2-7 — the tiled ABFP matmul (scan over K tiles: O(M*N) live memory)
# ---------------------------------------------------------------------------


def abfp_matmul(
    x: Array,
    w: Array,
    cfg: QuantConfig,
    key: Optional[Array] = None,
    tile_gains: Optional[Array] = None,
) -> Array:
    """y = ABFP(x @ w) with x: (..., K), w: (K, N) -> (..., N).

    Pure-jnp production path (``mode="abfp_ref"``).  Scans over the K tiles so
    the (T, M, N) partial-product tensor is never materialized; each scan step
    simulates one analog tile dot product:

        y_q[t] = Q(G * (x_q[t] . w_q[t]) + E; n*delta_y, tau_y = n)   (Eq. 7)
        y     += y_q[t] * s_x[t] * s_w[t] / G                         (Eq. 6)

    ``tile_gains`` (shape (T,), e.g. from ``adaptive_tile_gains``) swaps the
    global G for a per-tile G_t: amplified before the ADC quantizer in each
    scan step, divided out in that step's accumulation — the reference
    semantics of the fused kernel's per-tile gain path.
    """
    if key is None and cfg.noise_lsb > 0.0:
        raise ValueError("noise_lsb > 0 requires a PRNG key")

    batch_shape = x.shape[:-1]
    k_in, n_out = w.shape
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]

    x_q, s_x = quantize_input_tiles(x2, cfg)      # (M, T, n), (M, T)
    w_q, s_w = quantize_weight_tiles(w, cfg)      # (T, n, N), (T, N)
    t = w_q.shape[0]

    gain = jnp.float32(cfg.gain)
    bin_y = jnp.float32(cfg.bin_y)                # n * delta_y

    noisy = cfg.noise_lsb > 0.0
    if noisy:
        keys = jax.random.split(key, t)
    else:
        keys = jnp.zeros((t, 2), dtype=jnp.uint32)

    # XLA:CPU's small-dot emitter lacks a bf16 path (hit by eager tests at
    # tiny shapes); upcast codes there.  On TPU the bf16 codes feed the MXU
    # directly — values are identical either way (codes are exact integers).
    upcast = jax.default_backend() == "cpu"

    per_tile = tile_gains is not None
    if per_tile:
        g_ts = tile_gains.astype(jnp.float32)
    else:
        g_ts = jnp.ones((t,), jnp.float32)   # scanned but unused

    def step(acc, operand):
        xq_t, sx_t, wq_t, sw_t, key_t, g_t = operand
        if upcast:
            xq_t = xq_t.astype(jnp.float32)
            wq_t = wq_t.astype(jnp.float32)
        # Exact integer partial dot product (the analog MAC array output).
        p = jnp.dot(xq_t, wq_t, preferred_element_type=jnp.float32)  # (M, N)
        if noisy:
            e = jax.random.uniform(
                key_t, p.shape, jnp.float32,
                minval=-cfg.noise_lsb, maxval=cfg.noise_lsb)
        else:
            e = None
        if per_tile:
            y_q = adc(p, cfg, e, tile_gain=g_t) * bin_y              # Eq. 7
            acc = acc + y_q * (sx_t[:, None] * sw_t[None, :]) / g_t  # Eq. 6
        else:
            y_q = adc(p, cfg, e) * bin_y                             # Eq. 7
            acc = acc + y_q * (sx_t[:, None] * sw_t[None, :]) / gain
        return acc, None

    acc0 = jnp.zeros((m, n_out), dtype=cfg.accum_dtype)
    xs = (
        jnp.moveaxis(x_q, -2, 0),   # (T, M, n)
        jnp.moveaxis(s_x, -1, 0),   # (T, M)
        w_q,                        # (T, n, N)
        s_w,                        # (T, N)
        keys,
        g_ts,
    )
    acc, _ = jax.lax.scan(step, acc0, xs)
    return acc.reshape(*batch_shape, n_out).astype(cfg.out_dtype)


# ---------------------------------------------------------------------------
# Sec. IV-A — QAT: straight-through estimator (Eq. 8)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def abfp_matmul_ste(x: Array, w: Array, cfg: QuantConfig,
                    key: Optional[Array] = None) -> Array:
    """ABFP forward, straight-through backward (gradients of the plain matmul).

    Eq. 8: dL/dx = dL/dy . W^T, dL/dW = x^T . dL/dy — accumulated in FLOAT32.
    """
    return abfp_matmul(x, w, cfg, key)


def _ste_fwd(x, w, cfg, key):
    return abfp_matmul(x, w, cfg, key), (x, w)


def _ste_bwd(cfg, res, g):
    x, w = res
    g32 = g.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    dx = jnp.matmul(g32, w32.T).astype(x.dtype)
    g2 = g32.reshape(-1, g32.shape[-1])
    x2 = x32.reshape(-1, x32.shape[-1])
    dw = jnp.matmul(x2.T, g2).astype(w.dtype)
    return dx, dw, None  # no gradient w.r.t. the PRNG key


abfp_matmul_ste.defvjp(_ste_fwd, _ste_bwd)


def quantize_ste(v: Array, delta, tau) -> Array:
    """Elementwise STE quantizer: forward Q(v), backward identity."""
    q = quantize(jax.lax.stop_gradient(v), delta, tau)
    return v + jax.lax.stop_gradient(q - v)


# ---------------------------------------------------------------------------
# Digital fixed-point aside (Sec. III-A): accumulate-then-quantize
# ---------------------------------------------------------------------------


def digital_bfp_matmul(x: Array, w: Array, cfg: QuantConfig) -> Array:
    """The *digital* accelerator ordering (the paper's aside under Eq. 4).

    A digital fixed-point device keeps a wide accumulator
    (b_W + b_X + log2(n) + log2(T) bits fit comfortably in int32), so the
    summation across tiles happens BEFORE any output quantization: the only
    quantization error is the input/weight rounding.  An AMS device must pass
    every tile's partial product through the b_Y-bit ADC (Eq. 3), which is why
    it suffers more quantization error.  Used by tests/benchmarks to reproduce
    that claim quantitatively.
    """
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q, s_x = quantize_input_tiles(x2, cfg)
    w_q, s_w = quantize_weight_tiles(w, cfg)
    # Exact partial products, rescaled and accumulated with no ADC in the loop.
    p = jnp.einsum("mtn,tno->tmo", x_q, w_q,
                   preferred_element_type=jnp.float32)
    dd = jnp.float32(float(cfg.delta_x * cfg.delta_w))
    y = jnp.einsum("tmo,mt,to->mo", p * dd, s_x, s_w)
    return y.reshape(*batch_shape, w.shape[1]).astype(cfg.out_dtype)
