"""ADC energy model — paper Sec. VI system-level analysis.

The mixed-signal converter power scales exponentially with bit precision
(~2^b) and linearly with gain.  This module reproduces the paper's
comparison against Rekhi et al. [6]: at iso-accuracy for ResNet50, ABFP with
tile 128 / gain 8 / 8 output bits vs. Rekhi's 12.5 ADC bits at tile 8:

    energy ratio = 2^(12.5 - 8) / 8  ~= 2.83x  less ADC energy
    throughput   = 128 / 8           =  16x    more MACs per cycle
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AmsDesignPoint:
    """One AMS hardware design point for the Sec. VI energy accounting."""

    tile_width: int        # n: MACs per analog clock (dot-product length)
    adc_bits: float        # b_Y
    gain: float = 1.0


def adc_energy(point: AmsDesignPoint) -> float:
    """Relative ADC energy per conversion: ~ 2^b * G (arbitrary units)."""
    return (2.0 ** point.adc_bits) * point.gain


def energy_per_mac(point: AmsDesignPoint) -> float:
    """One ADC conversion serves an n-long dot product."""
    return adc_energy(point) / point.tile_width


def energy_ratio(a: AmsDesignPoint, b: AmsDesignPoint) -> float:
    """ADC energy of design a relative to design b (per conversion, the
    paper's Sec. VI accounting)."""
    return adc_energy(a) / adc_energy(b)


def macs_per_cycle_ratio(a: AmsDesignPoint, b: AmsDesignPoint) -> float:
    """Throughput ratio of design a over design b (MACs per analog clock)."""
    return a.tile_width / b.tile_width


REKHI_RESNET50 = AmsDesignPoint(tile_width=8, adc_bits=12.5, gain=1.0)
ABFP_RESNET50 = AmsDesignPoint(tile_width=128, adc_bits=8.0, gain=8.0)


def paper_section6_comparison() -> dict:
    """Returns the paper's headline numbers (~2.8x energy, 16x MACs/cycle)."""
    return {
        "adc_energy_reduction": energy_ratio(REKHI_RESNET50, ABFP_RESNET50),
        "macs_per_cycle_gain": macs_per_cycle_ratio(ABFP_RESNET50, REKHI_RESNET50),
    }
