"""Differential Noise Finetuning (DNF) — paper Sec. IV-B.

DNF keeps the forward pass in FLOAT32/BFLOAT16 and adds, to each layer
output, noise sampled from a histogram of the *differential noise*

    dy^l = ABFP_layer^l(x^l) - FLOAT_layer^l(x^l)

captured ONCE before finetuning on a single batch, with both layers fed the
same FLOAT32 input (the previous FLOAT layer's output).  Histograms use the
paper's recipe: 100 bins, +0.5 smoothing of every bin count to avoid zero
probabilities.

The per-layer histograms are stored as stacked arrays so they can be indexed
inside a ``jax.lax.scan`` over layers, and sampling is inverse-CDF
(searchsorted) + uniform-within-bin — O(log bins) per draw, jit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

NUM_BINS_DEFAULT = 100
SMOOTHING_DEFAULT = 0.5


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NoiseHistogram:
    """Smoothed histogram distribution(s) of differential noise.

    Supports a leading "layer" axis: ``edges (L, B+1)``, ``cum (L, B)`` so a
    stacked histogram can be carried through scan-over-layers and indexed with
    the loop counter.  Also stores per-layer mean/std for the paper's Fig. 5
    style layer-susceptibility analysis.
    """

    edges: Array   # (..., B+1) bin edges
    cum: Array     # (..., B)   cumulative probabilities, last value == 1
    mean: Array    # (...)      mean of the raw differential noise
    std: Array     # (...)      std  of the raw differential noise

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        """Flatten to (edges, cum, mean, std) children; no static aux."""
        return (self.edges, self.cum, self.mean, self.std), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from ``tree_flatten`` output."""
        return cls(*children)

    # -- construction -------------------------------------------------------
    @classmethod
    def fit(
        cls,
        samples: Array,
        num_bins: int = NUM_BINS_DEFAULT,
        smoothing: float = SMOOTHING_DEFAULT,
    ) -> "NoiseHistogram":
        """Fit a single histogram to a sample tensor (flattened)."""
        s = np.asarray(samples, dtype=np.float32).ravel()
        s = s[np.isfinite(s)]
        if s.size == 0:
            s = np.zeros((1,), np.float32)
        lo, hi = float(s.min()), float(s.max())
        if lo == hi:  # degenerate: widen so sampling returns ~ the constant
            pad = max(1e-6, 1e-4 * abs(lo))
            lo, hi = lo - pad, hi + pad
        counts, edges = np.histogram(s, bins=num_bins, range=(lo, hi))
        probs = (counts + smoothing) / (counts.sum() + smoothing * num_bins)
        cum = np.cumsum(probs)
        cum[-1] = 1.0
        return cls(
            edges=jnp.asarray(edges),
            cum=jnp.asarray(cum, dtype=jnp.float32),
            mean=jnp.asarray(s.mean(), dtype=jnp.float32),
            std=jnp.asarray(s.std(), dtype=jnp.float32),
        )

    @classmethod
    def stack(cls, hists: list["NoiseHistogram"]) -> "NoiseHistogram":
        """Stack per-layer histograms along a leading axis (for lax.scan)."""
        return cls(
            edges=jnp.stack([h.edges for h in hists]),
            cum=jnp.stack([h.cum for h in hists]),
            mean=jnp.stack([h.mean for h in hists]),
            std=jnp.stack([h.std for h in hists]),
        )

    def layer(self, idx) -> "NoiseHistogram":
        """Select one layer's histogram from a stacked capture."""
        return NoiseHistogram(
            edges=self.edges[idx], cum=self.cum[idx],
            mean=self.mean[idx], std=self.std[idx],
        )

    # -- sampling (Eq. 9) ----------------------------------------------------
    def sample(self, key: Array, shape) -> Array:
        """Inverse-CDF sampling: xi ~ P_hist."""
        k1, k2 = jax.random.split(key)
        u = jax.random.uniform(k1, shape, dtype=jnp.float32)
        idx = jnp.searchsorted(self.cum, u, side="left")
        idx = jnp.clip(idx, 0, self.cum.shape[-1] - 1)
        lo = self.edges[idx]
        hi = self.edges[idx + 1]
        frac = jax.random.uniform(k2, shape, dtype=jnp.float32)
        return lo + (hi - lo) * frac


def capture_differential_noise(
    float_out: Array,
    abfp_out: Array,
    num_bins: int = NUM_BINS_DEFAULT,
    smoothing: float = SMOOTHING_DEFAULT,
) -> NoiseHistogram:
    """dy = ABFP(x) - FLOAT(x) for one layer, fitted to a histogram.

    Both outputs must come from the SAME input (the previous FLOAT layer's
    output) — the framework's paired-capture mode guarantees this.
    """
    dy = np.asarray(abfp_out, np.float32) - np.asarray(float_out, np.float32)
    return NoiseHistogram.fit(dy, num_bins=num_bins, smoothing=smoothing)


def inject(y: Array, hist: Optional[NoiseHistogram], key: Optional[Array]) -> Array:
    """Eq. 9: y^l = f^l(x^l) + xi^l,  xi^l ~ P_hist^l (no-op when hist is None)."""
    if hist is None:
        return y
    xi = hist.sample(key, y.shape).astype(y.dtype)
    return y + xi


def select_layers_by_std(
    hists: list[NoiseHistogram], top_fraction: float
) -> list[bool]:
    """Paper Sec. V-B: restrict injection to the layers with the highest
    differential-noise std (higher variance = more susceptible), which is how
    the paper tailors DNF to SSD-ResNet34 to cut sampling overhead."""
    stds = np.array([float(h.std) for h in hists])
    k = max(1, int(round(top_fraction * len(hists))))
    thresh = np.sort(stds)[-k]
    return [bool(s >= thresh) for s in stds]
