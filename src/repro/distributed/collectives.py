"""Distributed-optimization tricks: gradient compression + overlap notes.

Gradient compression for the data-parallel all-reduce:
  * ``bf16``  — halve DP gradient traffic (safe default at LM scale).
  * ``int8``  — 4x reduction with per-tensor scale and *error feedback*
    (the residual of the quantization is carried into the next step so the
    compression is unbiased over time — standard EF-SGD construction).

Under pjit/GSPMD the all-reduce is implicit in the sharded grad computation;
compression is therefore expressed as a (compress -> all-reduce-width) pair
around the optimizer boundary: cast/quantize the grads *before* they cross
the data axis.  The helpers are pure pytree transforms and compose with any
optimizer in ``repro.optim``.

Compute/communication overlap: with scan-over-layers, XLA's latency-hiding
scheduler overlaps the per-layer reduce-scatter with the next layer's
backward matmuls automatically once grads are bucketed per scan step — which
the stacked-parameter layout already provides (one fused collective per
leaf, pipelined across scan iterations).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class ErrorFeedbackState(NamedTuple):
    residual: Pytree     # f32 compression residuals (same structure as grads)


def init_error_feedback(params: Pytree) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_bf16(grads: Pytree) -> Pytree:
    """Cast-compress: the all-reduce runs at half width."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def compress_int8_ef(
    grads: Pytree, ef: ErrorFeedbackState
) -> Tuple[Pytree, Pytree, ErrorFeedbackState]:
    """int8 + per-tensor scale + error feedback.

    Returns (q_grads int8, scales f32, new_ef).  The residual
    (g + r) - dequant(q) is carried to the next step.
    """
    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale
        return q, scale, new_r

    qs, scales, residuals = [], [], []
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = jax.tree.leaves(ef.residual)
    for g, r in zip(leaves, ef_leaves):
        q, s, nr = one(g, r)
        qs.append(q)
        scales.append(s)
        residuals.append(nr)
    unflat = lambda xs: jax.tree.unflatten(treedef, xs)  # noqa: E731
    return unflat(qs), unflat(scales), ErrorFeedbackState(unflat(residuals))


def decompress_int8(q_grads: Pytree, scales: Pytree) -> Pytree:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_grads, scales)


def apply_compression(grads: Pytree, method: Optional[str],
                      ef: Optional[ErrorFeedbackState] = None):
    """One-call wrapper used by the train step.  Returns (grads, new_ef)."""
    if method is None or method == "none":
        return grads, ef
    if method == "bf16":
        return decompress_bf16(compress_bf16(grads)), ef
    if method == "int8":
        assert ef is not None
        q, s, new_ef = compress_int8_ef(grads, ef)
        return decompress_int8(q, s), new_ef
    raise ValueError(f"unknown compression {method!r}")
