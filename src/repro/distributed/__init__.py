"""repro.distributed — sharding rules, collectives, fault tolerance."""

from repro.distributed import collectives, fault, sharding  # noqa: F401
