"""Fault tolerance + straggler mitigation + elastic scaling policy.

The single-process container can't kill real hosts, so this module provides
the *policy machinery* the launcher runs, with the host-failure signal
injectable (tests inject synthetic failures; a real deployment wires
``jax.monitoring``/GCS health checks into the same hooks):

  * ``RestartPolicy``   — crash-loop-aware resume decision: restore the
    newest *valid* checkpoint (corrupt ones are skipped by
    ``checkpoint.restore``), with bounded restarts per time window.
  * ``StragglerMonitor``— per-step deadline from a trailing-median model;
    steps exceeding ``k * median`` are flagged, and the policy escalates:
    log -> re-slice (skip straggling host's shard next step) -> checkpoint &
    re-mesh without it (elastic down-scale).
  * ``ElasticPlan``     — given a lost-host count, choose the largest valid
    (data, model) mesh that the remaining chips support and report the
    resharding plan; checkpoints are logical-layout so the restore path in
    ``repro.checkpoint`` already handles the move.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    window_sec: float = 3600.0
    _restarts: List[float] = dataclasses.field(default_factory=list)

    def should_restart(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        self._restarts = [t for t in self._restarts
                          if now - t < self.window_sec]
        if len(self._restarts) >= self.max_restarts:
            return False            # crash loop: surface to operator
        self._restarts.append(now)
        return True


@dataclasses.dataclass
class StragglerMonitor:
    """Trailing-median step-time model with a k-times deadline."""

    k: float = 3.0
    history: int = 32
    _times: List[float] = dataclasses.field(default_factory=list)
    flagged: int = 0

    def deadline(self) -> Optional[float]:
        if len(self._times) < 5:
            return None
        s = sorted(self._times)
        return self.k * s[len(s) // 2]

    def observe(self, step_time: float) -> bool:
        """Record a step; returns True if it breached the deadline."""
        d = self.deadline()
        breach = d is not None and step_time > d
        self._times.append(step_time)
        self._times = self._times[-self.history:]
        if breach:
            self.flagged += 1
        return breach

    def escalation(self) -> str:
        """log -> reslice -> remesh as breaches accumulate."""
        if self.flagged <= 2:
            return "log"
        if self.flagged <= 5:
            return "reslice"
        return "remesh"


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple
    new_shape: tuple
    lost_hosts: int

    @property
    def changed(self) -> bool:
        return self.old_shape != self.new_shape


def plan_elastic_mesh(chips_available: int, model_parallel: int,
                      old_shape: tuple) -> ElasticPlan:
    """Largest (data, model) mesh under the surviving chip count, holding the
    model axis fixed (weights' TP layout is the expensive one to move)."""
    data = chips_available // model_parallel
    if data < 1:
        raise RuntimeError(
            f"{chips_available} chips cannot hold model_parallel="
            f"{model_parallel}")
    new_shape = (data, model_parallel)
    lost = int((old_shape[0] * old_shape[1] - chips_available))
    return ElasticPlan(tuple(old_shape), new_shape, max(lost, 0))


def plan_recovery_mesh(chips_available: int, model_parallel: int,
                       old_shape: tuple) -> ElasticPlan:
    """``plan_elastic_mesh`` for fault recovery: degrade the model axis
    when the surviving chips cannot hold it.

    Holding TP fixed is the cheap move only while all model banks are
    healthy; after a shard-drop recovery the weights are re-programmed
    from the clean master anyway (``serving.engine``), so a narrower model
    axis is admissible.  Raises like ``plan_elastic_mesh`` only when no
    chips survive at all.
    """
    if chips_available < 1:
        raise RuntimeError("no surviving chips to re-mesh onto")
    mp = max(1, min(model_parallel, chips_available))
    return plan_elastic_mesh(chips_available, mp, old_shape)
