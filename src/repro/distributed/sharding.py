"""Sharding rules: parameter-path → PartitionSpec, ZeRO-1 state sharding.

Megatron-style tensor parallelism over the 'model' axis + data parallelism
over ('pod', 'data'):

  wq/wk/wv        (d, heads*hd)  -> shard output (heads) over 'model'
  wo              (heads*hd, d)  -> shard input  (heads) over 'model'
  mlp wi/wg       (d, ff)        -> shard ff over 'model'
  mlp wo          (ff, d)        -> shard ff over 'model'
  moe wi/wg/wo    (E, d, ff)     -> shard experts over 'model' (EP)
  embed           (V, d)         -> shard vocab over 'model'
  lm_head         (d, V)         -> shard vocab over 'model'
  recurrent/xlstm projections    -> shard the wide axis over 'model'
  norms / scalars                -> replicated

Stacked-layer leaves carry a leading (n_groups,) scan axis: specs are
shifted right by one.  Activations: batch over ('pod', 'data').

ZeRO-1: optimizer moments and f32 masters additionally shard their largest
replicated axis over 'data' when divisible — cutting optimizer memory by the
DP degree, the standard trick for fitting large models.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.abfp import PackedWeight

Pytree = Any

MODEL_AXIS = "model"
DATA_AXES = ("pod", "data")      # 'pod' present only on the multi-pod mesh
_LANE = 128                      # PackedWeight column alignment (core.abfp)


def _data_axes(mesh: Mesh):
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def validate_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding on any dim not divisible by its axis-group size.

    Production meshes meet most configs exactly; the exceptions (vocab 51865
    whisper / 49155 granite, global_batch=1 long-context cells) degrade to
    replication on that dim instead of failing to lower.
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, parts[: len(shape)]):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def batch_spec(mesh: Mesh, shape: tuple) -> P:
    """Activations / token batches: batch dim over (pod, data), validated."""
    spec = P(_data_axes(mesh), *([None] * (len(shape) - 1)))
    return validate_spec(spec, tuple(shape), mesh)


# Rules matched against the *last* path components (innermost name wins).
# value = spec for the UNSTACKED 2-D/3-D weight.
_RULES = [
    # attention projections
    (("attn", "wq"), P(None, MODEL_AXIS)),
    (("attn", "wk"), P(None, MODEL_AXIS)),
    (("attn", "wv"), P(None, MODEL_AXIS)),
    (("attn", "wo"), P(MODEL_AXIS, None)),
    (("cross", "wq"), P(None, MODEL_AXIS)),
    (("cross", "wk"), P(None, MODEL_AXIS)),
    (("cross", "wv"), P(None, MODEL_AXIS)),
    (("cross", "wo"), P(MODEL_AXIS, None)),
    # dense MLP
    (("mlp", "wi"), P(None, MODEL_AXIS)),
    (("mlp", "wg"), P(None, MODEL_AXIS)),
    (("mlp", "wo"), P(MODEL_AXIS, None)),
    # MoE: expert parallelism
    (("moe", "router"), P(None, None)),
    (("moe", "wi"), P(MODEL_AXIS, None, None)),
    (("moe", "wg"), P(MODEL_AXIS, None, None)),
    (("moe", "wo"), P(MODEL_AXIS, None, None)),
    # Griffin recurrent block
    (("rglru", "w_in"), P(None, MODEL_AXIS)),
    (("rglru", "w_gate"), P(None, MODEL_AXIS)),
    (("rglru", "w_rg"), P(None, MODEL_AXIS)),
    (("rglru", "w_ig"), P(None, MODEL_AXIS)),
    (("rglru", "w_out"), P(MODEL_AXIS, None)),
    (("rglru", "conv_w"), P(None, MODEL_AXIS)),
    (("rglru", "lam"), P(MODEL_AXIS)),
    # xLSTM
    (("mlstm", "w_up"), P(None, MODEL_AXIS)),
    (("mlstm", "w_gate"), P(None, MODEL_AXIS)),
    (("mlstm", "wq"), P(None, MODEL_AXIS)),
    (("mlstm", "wk"), P(None, MODEL_AXIS)),
    (("mlstm", "wv"), P(None, MODEL_AXIS)),
    (("mlstm", "w_if"), P(None, None)),
    (("mlstm", "w_down"), P(MODEL_AXIS, None)),
    (("mlstm", "skip_scale"), P(MODEL_AXIS)),
    (("slstm", "w_x"), P(None, MODEL_AXIS)),
    (("slstm", "r_h"), P(None, None, None)),   # block-diagonal, small
    (("slstm", "b"), P(None)),
    (("slstm", "w_up"), P(None, MODEL_AXIS)),
    (("slstm", "w_down"), P(MODEL_AXIS, None)),
    # embeddings / head
    (("embed",), P(MODEL_AXIS, None)),
    (("lm_head",), P(None, MODEL_AXIS)),
]


def _path_names(path) -> tuple:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def _match(names: tuple) -> Optional[P]:
    for pattern, spec in _RULES:
        m = len(pattern)
        # match pattern against the tail, ignoring numeric path components
        filt = tuple(n for n in names if not n.isdigit())
        if filt[-m:] == pattern:
            return spec
    return None


def _is_stacked(names: tuple) -> bool:
    """Leaves under groups/<j>/... or encoder/layers/... have a leading scan
    axis."""
    return ("groups" in names) or ("layers" in names)


def _leaf_base_spec(names: tuple, ndim: int) -> P:
    """Rule-matched, rank-adjusted spec for one leaf (unvalidated)."""
    spec = _match(names)
    if spec is None:
        return P(*([None] * ndim))                  # norms, biases, scalars
    if _is_stacked(names):
        spec = P(None, *spec)                       # leading scan axis
    if len(spec) != ndim:
        # rank mismatch (e.g. lam under stacking) — pad/trim safely
        parts = tuple(spec) + (None,) * max(0, ndim - len(spec))
        spec = P(*parts[:ndim])
    return spec


def _leaf_demote_k(names: tuple, ndim: int, spec: P) -> P:
    """Drop MODEL sharding from a weight's contraction (K) axis — ABFP
    tiles of width n must not straddle shards and the tile scan axis must
    not be sharded (see ``abfp_param_spec_tree``)."""
    parts = list(spec)
    if not parts:
        return spec
    # Stacked leaves: axis 0 is the scan axis; K is the first non-stack
    # axis for 2-D weights (rank>=2 after stacking).
    k_axis = 1 if _is_stacked(names) else 0
    if ndim >= 2 and len(parts) > k_axis and parts[k_axis] == MODEL_AXIS:
        parts[k_axis] = None
    # MoE expert axis (axis 0/1) is not a contraction — keep EP sharding.
    return P(*parts)


def param_spec_tree(params: Pytree, mesh: Optional[Mesh] = None) -> Pytree:
    """PartitionSpec pytree mirroring ``params`` (validated when mesh given)."""

    def one(path, leaf):
        spec = _leaf_base_spec(_path_names(path), leaf.ndim)
        if mesh is not None:
            spec = validate_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def shard_params(params: Pytree, mesh: Mesh) -> Pytree:
    specs = param_spec_tree(params)
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(mesh, s)), params, specs)


def named_sharding_tree(params: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_spec_tree(params))


def abfp_param_spec_tree(params: Pytree, mesh: Optional[Mesh] = None) -> Pytree:
    """Param specs for ABFP-simulation (QAT / ABFP-serve) cells.

    The ABFP tile scan requires the contraction (K) axis of every quantized
    matmul to be shard-local (tiles of width n must not straddle shards and
    the scan axis must not be sharded).  Column-parallel sharding (output
    features over 'model') is always safe; row-parallel specs (K over
    'model') are demoted to replicated.  See EXPERIMENTS.md §Dry-run.
    """
    def one(path, leaf):
        names = _path_names(path)
        spec = _leaf_demote_k(names, leaf.ndim,
                              _leaf_base_spec(names, leaf.ndim))
        if mesh is not None:
            spec = validate_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Serving placement: packed/float param trees + decode state, mesh-aware
# ---------------------------------------------------------------------------


def serving_param_spec_tree(params: Pytree, mesh: Mesh,
                            quant: Any = None) -> Pytree:
    """Column-parallel-only specs for a serving param tree (float or packed).

    Float leaves follow the ABFP rules (output features over 'model',
    K-sharding demoted): exactly the axes ``kernels.ops.dense_tp`` splits.
    ``PackedWeight`` leaves shard their int8 codes AND bf16 scales together
    along the output-column axis — the per-(tile, col) scales always travel
    with their codes.  Shard-or-replicate is decided by the SAME predicate
    the dispatch uses (``kernels.ops.tp_col_quantum``, given ``quant``), so
    a weight is stored sharded exactly when the matmul consumes it sharded
    — no per-call resharding either way.  Without ``quant`` the
    conservative noise-safe quantum (whole 128-lane blocks per shard)
    applies to kernel-mode weights.
    """
    from repro.kernels.ops import tp_col_quantum

    tp = mesh.shape[MODEL_AXIS] if MODEL_AXIS in mesh.axis_names else 1

    def col_quantum(packed: bool) -> Optional[int]:
        if quant is not None:
            return tp_col_quantum(quant, packed, tp)
        return tp * _LANE if packed else tp     # noise-safe default

    def one(path, leaf):
        if isinstance(leaf, PackedWeight):
            lead = leaf.ndim - 2
            q = col_quantum(True)
            col = (MODEL_AXIS
                   if tp > 1 and q is not None and leaf.n_padded % q == 0
                   else None)
            cs = P(*((None,) * (lead + 1)), col)
            # A PackedWeight of specs: flattens to (codes_spec, scales_spec
            # [, gains_spec]) with the SAME aux as the param leaf, so
            # jax.device_put can zip the two trees leaf-for-leaf.  Per-tile
            # gains index the (contracting) K axis — every column shard
            # needs the full vector — so they replicate.
            gs = (None if leaf.gains is None
                  else P(*((None,) * leaf.gains.ndim)))
            return PackedWeight(cs, cs, leaf.k, leaf.n_cols,
                                leaf.tile_width, leaf.bits_w, gains=gs)
        names = _path_names(path)
        spec = _leaf_demote_k(names, leaf.ndim,
                              _leaf_base_spec(names, leaf.ndim))
        spec = validate_spec(spec, leaf.shape, mesh)
        parts = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        if parts and parts[-1] == MODEL_AXIS:
            q = col_quantum(False)
            if q is None or leaf.shape[-1] % q != 0:
                spec = P(*parts[:-1], None)
        return spec

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedWeight))


def shard_serving_params(params: Pytree, mesh: Mesh,
                         quant: Any = None) -> Pytree:
    """Place a serving param tree (float and/or packed leaves) on ``mesh``."""
    specs = serving_param_spec_tree(params, mesh, quant)
    return jax.device_put(
        params,
        jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                     is_leaf=lambda x: isinstance(x, P)))


def serving_state_spec_tree(state: Pytree, mesh: Mesh) -> Pytree:
    """Decode-state specs for SERVING: slot/batch axis over the data axes,
    everything else replicated.

    Unlike ``decode_state_spec_tree`` (training-eval oriented), no state
    axis is put on 'model': serving activations are replicated across the
    model axis between column-parallel matmuls (``kernels.ops.dense_tp``
    all-gathers), and model-sharding KV heads would make attention
    contractions cross shards — trading the bit-identical-at-any-mesh-shape
    property for memory serving does not need at these capacities."""
    dp = _data_axes(mesh)

    def one(path, leaf):
        names = _path_names(path)
        if names[-1].endswith("_pages"):
            # Paged KV pools are GLOBAL (leading axis is the page pool, not
            # the slot batch): fully replicated so any data shard can gather
            # any page through its table rows.
            return P(*([None] * leaf.ndim))
        # "enc" leaves (cross-attention KV cached at admission) carry the
        # same leading (n_groups,) scan axis as grouped decode state.
        stacked = ("groups" in names) or ("enc" in names)
        nd = leaf.ndim - (1 if stacked else 0)
        if nd <= 0:
            return P(*([None] * leaf.ndim))
        core = (dp,) + (None,) * (nd - 1)
        if stacked:
            core = (None,) + core
        return validate_spec(P(*core), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, state)


def shard_decode_state(state: Pytree, mesh: Mesh) -> Pytree:
    """Place an ``init_decode_state`` tree on ``mesh`` for serving."""
    specs = serving_state_spec_tree(state, mesh)
    return jax.device_put(
        state, jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P)))


# ---------------------------------------------------------------------------
# Decode-state sharding
# ---------------------------------------------------------------------------


def decode_state_spec_tree(state: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpec tree for a ``models.init_decode_state`` pytree.

    Batch over (pod, data); the widest per-token axis over 'model' when
    divisible (KV heads, else head_dim; recurrent state width; mLSTM head
    dim).  Leaves under "groups" carry a leading stacked axis.
    """
    dp = _data_axes(mesh)
    mp = mesh.shape[MODEL_AXIS]

    def one(path, leaf):
        names = _path_names(path)
        stacked = "groups" in names
        name = names[-1]
        nd = leaf.ndim - (1 if stacked else 0)
        shape = leaf.shape[1:] if stacked else leaf.shape

        if name in ("length", "position"):
            core = (dp,)
        elif name in ("k", "v"):                   # (B, S, KH, HD)
            if shape[2] % mp == 0:
                core = (dp, None, MODEL_AXIS, None)
            elif shape[3] % mp == 0:
                core = (dp, None, None, MODEL_AXIS)
            else:
                core = (dp, None, None, None)
        elif name == "conv":                       # (B, W-1, R)
            core = (dp, None, MODEL_AXIS if shape[2] % mp == 0 else None)
        elif name == "C":                          # (B, NH, dh, dh)
            core = (dp, None, MODEL_AXIS if shape[2] % mp == 0 else None, None)
        elif nd == 3:                              # h/c/n/m (B, NH, dh)
            core = (dp, None, MODEL_AXIS if shape[2] % mp == 0 else None)
        elif nd == 2:                              # h (B, R) / m (B, NH)
            core = (dp, MODEL_AXIS if shape[1] % mp == 0 else None)
        else:
            core = (dp,) + (None,) * (nd - 1)
        if stacked:
            core = (None,) + tuple(core)
        return validate_spec(P(*core), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, state)


# ---------------------------------------------------------------------------
# ZeRO-1: shard optimizer state over the data axis too
# ---------------------------------------------------------------------------


def zero1_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Extend a param spec with 'data' sharding on the largest replicated,
    divisible axis (optimizer moments / master weights only)."""
    if "data" not in mesh.axis_names:
        return spec
    dp = mesh.shape["data"]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest axis currently unsharded and divisible by dp
    best, best_size = None, 0
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % dp == 0 and s > best_size:
            best, best_size = i, s
    if best is None:
        return spec
    parts[best] = "data"
    return P(*parts)


def zero1_state_sharding(params: Pytree, mesh: Mesh) -> Pytree:
    """NamedSharding tree for f32 moments/masters mirroring ``params``."""
    specs = param_spec_tree(params)

    def one(p, s):
        return NamedSharding(mesh, zero1_spec(s, p.shape, mesh))

    return jax.tree.map(one, params, specs)
