"""Fault-tolerant checkpointing.

Design (the checkpoint/restart half of the fault-tolerance story):
  * **Atomic**: write to ``step_XXXX.tmp/``, fsync, then rename — a crash
    mid-write can never corrupt the latest-valid checkpoint.
  * **Self-describing**: a manifest (tree structure + dtypes + shapes +
    framework step + PRNG state) travels with flat ``.npy`` leaves.
  * **Logical layout**: arrays are saved unsharded-logical (gathered), so a
    restore may use a *different* mesh — this is what makes elastic
    re-scaling (checkpoint → new mesh → reshard on load) work.
  * **keep_last_k** garbage collection, ``latest_step`` discovery, and
    integrity validation (manifest hash) for restart-after-failure.

On a real multi-host pod the per-leaf save becomes a per-shard save keyed by
``jax.process_index()`` with a barrier before rename; the layout and manifest
logic is identical, so the single-process implementation here is the same
code path the launcher uses.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_MANIFEST = "manifest.json"


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["_".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(directory: str, step: int, tree: Pytree, *, keep_last_k: int = 3,
         extra: Optional[dict] = None) -> str:
    """Atomically save ``tree`` as checkpoint ``step``; returns final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype: store raw uint16 view + dtype tag.
        dtype_tag = str(leaf.dtype)
        if dtype_tag == "bfloat16":
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "dtype": dtype_tag,
             "shape": list(arr.shape)})
    blob = json.dumps(manifest, sort_keys=True).encode()
    manifest["hash"] = hashlib.sha256(blob).hexdigest()
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                         # atomic publish
    _gc(directory, keep_last_k)
    return final


def _gc(directory: str, keep_last_k: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep_last_k] if keep_last_k > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _MANIFEST)):
                out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def validate(path: str) -> bool:
    """Integrity check: manifest readable + every leaf file present."""
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            if not os.path.exists(os.path.join(path, leaf["file"])):
                return False
        return True
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def restore(directory: str, like: Pytree, step: Optional[int] = None,
            ) -> Tuple[Pytree, int, dict]:
    """Restore into the structure of ``like``; returns (tree, step, extra).

    Falls back to the newest *valid* checkpoint if the latest is corrupt
    (restart-after-failure semantics).
    """
    steps = all_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    if not steps:
        raise FileNotFoundError(f"no checkpoint in {directory}")

    for s in reversed(steps):
        path = os.path.join(directory, f"step_{s:010d}")
        if validate(path):
            return _load(path, like), s, _extra(path)
    raise IOError(f"all checkpoints in {directory} are corrupt")


def _extra(path: str) -> dict:
    with open(os.path.join(path, _MANIFEST)) as f:
        return json.load(f).get("extra", {})


def _load(path: str, like: Pytree) -> Pytree:
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(like)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"model expects {len(leaves)}")
    out = []
    for leaf_like, meta in zip(leaves, manifest["leaves"]):
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16.dtype)
        restored = jnp.asarray(arr)
        target_shape = tuple(leaf_like.shape)
        assert restored.shape == target_shape, (meta["name"], restored.shape,
                                                target_shape)
        # Resharding happens by putting onto the *current* leaf's sharding —
        # this is where elastic re-scaling lands on a new mesh.
        if hasattr(leaf_like, "sharding"):
            restored = jax.device_put(restored, leaf_like.sharding)
        out.append(restored.astype(leaf_like.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
