"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 1:2
[arXiv:2402.19427; hf]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        d_ff=7680,
        vocab_size=256_000,
        mlp_type="geglu",
        embed_scale=True,
        tie_embeddings=True,
        # 1:2 attention:recurrent — (R, R, A) cycled over 26 layers.
        block_pattern=("recurrent", "recurrent", "attention"),
        window_size=2048,
        lru_width=2560,
        conv_width=4,
    )
