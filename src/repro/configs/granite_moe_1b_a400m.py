"""granite-moe-1b-a400m — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=512,              # per-expert hidden
        vocab_size=49_155,
        mlp_type="swiglu",
        num_experts=32,
        experts_per_token=8,
    )
