"""chatglm3-6b — RoPE 2d (partial rotary), GQA kv=2 [arXiv:2406.12793; hf]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13_696,
        vocab_size=65_024,
        mlp_type="swiglu",
        rope_fraction=0.5,   # "RoPE 2d": rotary on half the head dim
    )
