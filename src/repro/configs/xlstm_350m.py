"""xlstm-350m — alternating sLSTM + mLSTM blocks [arXiv:2405.04517;
unverified].  d_ff=0: the xLSTM blocks carry their own projections."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        block_pattern=("mlstm", "slstm"),
    )
