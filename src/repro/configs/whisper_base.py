"""whisper-base — enc-dec transformer backbone; the conv audio frontend is a
STUB (input_specs() provides precomputed frame embeddings)
[arXiv:2212.04356; unverified]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        num_layers=6,             # decoder layers
        num_encoder_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51_865,
        mlp_type="gelu",
        norm_type="layernorm",
        pos_type="absolute",
        is_encoder_decoder=True,
        frontend="audio_stub",
    )
