"""phi-3-vision-4.2b — phi3-mini backbone + CLIP vision STUB (input_specs()
provides precomputed patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32_064,
        mlp_type="swiglu",
        frontend="vision_stub",
    )
