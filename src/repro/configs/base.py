"""Model / shape configuration dataclasses and the architecture registry."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture definition (static, hashable for jit closure)."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // num_heads
    mlp_type: str = "swiglu"                # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"              # rmsnorm | layernorm
    rope_fraction: float = 1.0              # 0.5 = chatglm partial rotary
    rope_theta: float = 10_000.0
    pos_type: str = "rope"                  # rope | absolute (whisper)
    embed_scale: bool = False               # gemma-style sqrt(d) input scaling
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # hybrid (recurrentgemma / Griffin)
    block_pattern: Tuple[str, ...] = ()     # cycled over layers, e.g.
                                            # ("recurrent","recurrent","attention")
    window_size: int = 0                    # sliding-window attention width
    lru_width: int = 0                      # RG-LRU state width (0 => d_model)
    conv_width: int = 4                     # temporal conv in recurrent block
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # modality frontends are STUBS: input_specs() provides precomputed
    # frame/patch embeddings (see DESIGN.md).
    frontend: str = "none"                  # none | audio_stub | vision_stub
    # numerics
    param_dtype: Any = jnp.bfloat16
    activation_dtype: Any = jnp.bfloat16
    # attention memory blocking
    attn_chunk: int = 512
    # rematerialize each scanned layer's activations (training memory)
    remat: bool = False
    # Beyond-paper optimization: store the decode KV cache as int8 codes with
    # a per-(token, head) ABFP scale — the paper's per-vector scaling applied
    # to the serving memory bottleneck (~2x decode HBM traffic reduction).
    kv_quant: bool = False
    # Fused flash-attention Pallas kernel for inference attention (keeps the
    # O(S^2) score tile in VMEM — the dominant prefill memory term).  Off by
    # default: interpret-mode lowering is slow on CPU; enable on TPU.
    use_flash_attention: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def attention_type(self) -> str:
        """full | sliding | hybrid | recurrent-only."""
        if self.block_pattern:
            kinds = set(self.block_pattern)
            if kinds == {"attention"}:
                return "full"
            if "attention" in kinds:
                return "hybrid"
            return "recurrent"
        return "full"

    @property
    def supports_long_context_decode(self) -> bool:
        """long_500k runs only for sub-quadratic (SSM / hybrid-with-window)
        families — see DESIGN.md shape-skip table."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.window_size > 0:
            return True
        return False

    def layer_kind(self, layer_idx: int) -> str:
        if not self.block_pattern:
            return "attention"
        return self.block_pattern[layer_idx % len(self.block_pattern)]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned shapes (LM transformer shapes are seq_len x global_batch).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg_fn):
    """Decorator: registers ``<module>.config()`` under its arch id."""
    cfg = cfg_fn()
    _REGISTRY[cfg.name] = cfg_fn
    return cfg_fn


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # Import the configs package lazily so registration side-effects run.
        import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests: small depth/width,
    few experts, tiny vocab — same code paths."""
    cfg = get_config(name)
    updates = dict(
        num_layers=min(cfg.num_layers, len(cfg.block_pattern) or 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=(min(cfg.num_kv_heads, 2)
                      if cfg.num_kv_heads < cfg.num_heads else 4),
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        lru_width=128 if cfg.lru_width else 0,
        window_size=min(cfg.window_size, 64) if cfg.window_size else 0,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        param_dtype=jnp.float32,
        activation_dtype=jnp.float32,
        attn_chunk=64,
    )
    if cfg.block_pattern:
        updates["num_layers"] = len(cfg.block_pattern)
    return dataclasses.replace(cfg, **updates)
