"""gemma-7b — GeGLU, head_dim=256, 16H/16KV [arXiv:2403.08295; hf]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24_576,
        vocab_size=256_000,
        mlp_type="geglu",
        embed_scale=True,
        tie_embeddings=True,
    )
