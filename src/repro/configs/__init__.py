"""repro.configs — the 10 assigned architectures + shapes + registry."""

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_archs,
    smoke_config,
)

# Importing registers each architecture.
from repro.configs import (  # noqa: F401
    chatglm3_6b,
    gemma_7b,
    granite_moe_1b_a400m,
    kimi_k2_1t_a32b,
    phi_3_vision_4_2b,
    recurrentgemma_2b,
    smollm_360m,
    tinyllama_1_1b,
    whisper_base,
    xlstm_350m,
)

ALL_ARCHS = list_archs()
