"""Deterministic synthetic LM data pipeline.

A learnable next-token task with real structure (so finetuning experiments
have signal): tokens follow a sparse random Markov chain over the vocab,
generated counter-based from (seed, step, shard) — any step's batch can be
recomputed exactly on any host, which is what makes checkpoint-resume and
elastic re-sharding deterministic with *no* data-state file.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4      # successors per token in the Markov chain


def _transition_table(cfg: DataConfig) -> np.ndarray:
    """(vocab, branching) successor table — the task's hidden structure."""
    rng = np.random.default_rng(cfg.seed + 7)
    return rng.integers(0, cfg.vocab_size,
                        size=(cfg.vocab_size, cfg.branching), dtype=np.int32)


def batch_at_step(cfg: DataConfig, step: int,
                  table: Optional[np.ndarray] = None) -> dict:
    """Counter-based batch: {tokens (B, S+1)} for step ``step``.

    tokens[:, :-1] are inputs, tokens[:, 1:] are labels.  Branch choice is
    geometric-skewed (p ~ 2^-i) so the task has a learnable optimum well
    above chance: a perfect model picks branch 0 (~53% accuracy at b=4)
    instead of 1/branching.
    """
    if table is None:
        table = _transition_table(cfg)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k0, kb = jax.random.split(key)
    b, s = cfg.global_batch, cfg.seq_len
    start = jax.random.randint(k0, (b,), 0, cfg.vocab_size, dtype=jnp.int32)
    logits = -jnp.arange(cfg.branching, dtype=jnp.float32) * jnp.log(2.0)
    branch = jax.random.categorical(kb, logits, shape=(b, s)).astype(jnp.int32)
    tbl = jnp.asarray(table)

    def step_fn(tok, br):
        nxt = tbl[tok, br]
        return nxt, nxt

    _, seqs = jax.lax.scan(step_fn, start, branch.T)
    tokens = jnp.concatenate([start[:, None], seqs.T], axis=1)  # (B, S+1)
    return {"tokens": tokens}


class SyntheticDataset:
    """Iterator facade with explicit step state (resumable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._table = _transition_table(cfg)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = batch_at_step(self.cfg, self.step, self._table)
        self.step += 1
        return batch
