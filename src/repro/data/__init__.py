"""repro.data — deterministic synthetic pipeline."""
from repro.data.synthetic import DataConfig, SyntheticDataset, batch_at_step  # noqa: F401
