"""repro.data — deterministic synthetic pipeline."""
from repro.data.synthetic import (  # noqa: F401
    DataConfig,
    SyntheticDataset,
    batch_at_step,
)
