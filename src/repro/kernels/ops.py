"""Unified dense-matmul dispatch: the single entry point models use.

``dense(x, w, cfg, key)`` routes to:
  * ``mode="float"``       — plain matmul in the operand dtype (FLOAT baseline)
  * ``mode="abfp_ref"``    — pure-jnp scan ABFP (core.abfp.abfp_matmul)
  * ``mode="abfp_kernel"`` — fused Pallas kernel (abfp_matmul_pallas)
  * ``mode="abfp_packed"`` — packed Pallas kernel: the weight is quantized
    once (``pack_abfp_weight``) and the kernel streams int8 codes + bf16
    scales from HBM.  ``dense`` packs a raw array on the fly (so QAT code
    can flip the mode switch); ``dense_packed`` takes an already-packed
    ``PackedWeight`` — the quantize-once serving path.

All ABFP modes carry the straight-through estimator (paper Eq. 8): the
backward pass is that of the plain matmul, accumulated in FLOAT32 — this is
what makes the same call usable for inference simulation AND for QAT.  For
``dense_packed`` the original float weight no longer exists, so the STE
weight matmul uses the dequantized lattice (the values the forward actually
multiplied by) and the packed weight itself gets a zero cotangent — packed
weights are frozen by construction.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abfp import (
    PackedWeight,
    QuantConfig,
    abfp_matmul,
    dequantize_packed,
    pack_abfp_weight,
)
from repro.kernels.abfp_matmul import (
    abfp_matmul_packed_pallas,
    abfp_matmul_pallas,
)


def _key_to_seed(key: Optional[jax.Array]) -> Optional[jax.Array]:
    """Fold a jax PRNG key into the int32 seed the Pallas hash PRNG expects."""
    if key is None:
        return None
    data = jax.random.key_data(key).astype(jnp.uint32)
    return jnp.bitwise_xor(data[..., 0], data[..., -1]).astype(jnp.int32)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def dense(x: jax.Array, w: jax.Array, cfg: QuantConfig,
          key: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ w (K, N) -> (..., N) under the QuantConfig's mode."""
    return _dense_fwd_impl(x, w, cfg, key)


def _dense_fwd_impl(x, w, cfg, key):
    if cfg.mode == "float":
        return jnp.matmul(x, w.astype(x.dtype))
    if cfg.mode == "abfp_ref":
        return abfp_matmul(x, w, cfg, key)
    if cfg.mode == "abfp_kernel":
        return abfp_matmul_pallas(x, w, cfg, _key_to_seed(key))
    if cfg.mode == "abfp_packed":
        pw = pack_abfp_weight(w, cfg)
        return abfp_matmul_packed_pallas(x, pw, cfg, _key_to_seed(key))
    raise ValueError(f"unknown quant mode: {cfg.mode!r}")


def _dense_fwd(x, w, cfg, key):
    return _dense_fwd_impl(x, w, cfg, key), (x, w)


def _dense_bwd(cfg, res, g):
    # STE (Eq. 8): gradients of the un-quantized matmul, FLOAT32 accumulation.
    x, w = res
    g32 = g.astype(jnp.float32)
    dx = jnp.matmul(g32, w.astype(jnp.float32).T).astype(x.dtype)
    g2 = g32.reshape(-1, g32.shape[-1])
    x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    dw = jnp.matmul(x2.T, g2).astype(w.dtype)
    return dx, dw, None


dense.defvjp(_dense_fwd, _dense_bwd)


# ---------------------------------------------------------------------------
# Pre-packed weights: the quantize-once serving entry point
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def dense_packed(x: jax.Array, pw: PackedWeight, cfg: QuantConfig,
                 key: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ packed weight (K, N) -> (..., N) via the packed kernel.

    ``pw`` is produced once by ``pack_abfp_weight`` (or ``pack_model_params``
    over a whole model); every call skips the weight max/round/clip work the
    plain kernel redoes per grid step.
    """
    return abfp_matmul_packed_pallas(x, pw, cfg, _key_to_seed(key))


def _dense_packed_fwd(x, pw, cfg, key):
    return dense_packed(x, pw, cfg, key), (x, pw)


def _dense_packed_bwd(cfg, res, g):
    # STE (Eq. 8) against the dequantized lattice; packed leaves are frozen.
    x, pw = res
    g32 = g.astype(jnp.float32)
    w = dequantize_packed(pw)                                # (K, N) f32
    dx = jnp.matmul(g32, w.T).astype(x.dtype)
    zero_codes = np.zeros(pw.codes.shape, dtype=jax.dtypes.float0)
    dpw = PackedWeight(zero_codes, jnp.zeros_like(pw.scales),
                       pw.k, pw.n_cols, pw.tile_width, pw.bits_w)
    return dx, dpw, None


dense_packed.defvjp(_dense_packed_fwd, _dense_packed_bwd)
