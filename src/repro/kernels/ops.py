"""Unified dense-matmul dispatch: the single entry point models use.

``dense(x, w, cfg, key)`` routes to:
  * ``mode="float"``       — plain matmul in the operand dtype (FLOAT baseline)
  * ``mode="abfp_ref"``    — pure-jnp scan ABFP (core.abfp.abfp_matmul)
  * ``mode="abfp_kernel"`` — fused Pallas kernel (abfp_matmul_pallas)
  * ``mode="abfp_packed"`` — packed Pallas kernel: the weight is quantized
    once (``pack_abfp_weight``) and the kernel streams int8 codes + bf16
    scales from HBM.  ``dense`` packs a raw array on the fly (so QAT code
    can flip the mode switch); ``dense_packed`` takes an already-packed
    ``PackedWeight`` — the quantize-once serving path.
  * ``mode="abfp_fused"``  — the packed path plus the paper's per-tile
    ADC gains (packed with ``adaptive_gain=True``, applied inside the
    kernel) and, at serving decode ticks, the fused QKV + attention
    kernels of ``kernels.abfp_decode_fused`` (dispatched by
    ``models.layers.attention_block``; every non-decode matmul runs the
    packed kernel with gains).

All ABFP modes carry the straight-through estimator (paper Eq. 8): the
backward pass is that of the plain matmul, accumulated in FLOAT32 — this is
what makes the same call usable for inference simulation AND for QAT.  For
``dense_packed`` the original float weight no longer exists, so the STE
weight matmul uses the dequantized lattice (the values the forward actually
multiplied by) and the packed weight itself gets a zero cotangent — packed
weights are frozen by construction.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abfp import (
    PackedWeight,
    QuantConfig,
    abfp_matmul,
    dequantize_packed,
    pack_abfp_weight,
)
from repro.kernels.abfp_matmul import (
    abfp_matmul_packed_pallas,
    abfp_matmul_pallas,
)


def _key_to_seed(key: Optional[jax.Array]) -> Optional[jax.Array]:
    """Fold a jax PRNG key into the int32 seed the Pallas hash PRNG expects."""
    if key is None:
        return None
    data = jax.random.key_data(key).astype(jnp.uint32)
    return jnp.bitwise_xor(data[..., 0], data[..., -1]).astype(jnp.int32)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def dense(x: jax.Array, w: jax.Array, cfg: QuantConfig,
          key: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ w (K, N) -> (..., N) under the QuantConfig's mode."""
    return _dense_fwd_impl(x, w, cfg, key)


def _dense_fwd_impl(x, w, cfg, key):
    if cfg.mode == "float":
        return jnp.matmul(x, w.astype(x.dtype))
    if cfg.mode == "abfp_ref":
        return abfp_matmul(x, w, cfg, key)
    if cfg.mode == "abfp_kernel":
        return abfp_matmul_pallas(x, w, cfg, _key_to_seed(key))
    if cfg.mode in ("abfp_packed", "abfp_fused"):
        pw = pack_abfp_weight(w, cfg,
                              adaptive_gain=(cfg.mode == "abfp_fused"))
        return abfp_matmul_packed_pallas(x, pw, cfg, _key_to_seed(key))
    raise ValueError(f"unknown quant mode: {cfg.mode!r}")


def _dense_fwd(x, w, cfg, key):
    return _dense_fwd_impl(x, w, cfg, key), (x, w)


def _dense_bwd(cfg, res, g):
    # STE (Eq. 8): gradients of the un-quantized matmul, FLOAT32 accumulation.
    x, w = res
    g32 = g.astype(jnp.float32)
    dx = jnp.matmul(g32, w.astype(jnp.float32).T).astype(x.dtype)
    g2 = g32.reshape(-1, g32.shape[-1])
    x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    dw = jnp.matmul(x2.T, g2).astype(w.dtype)
    return dx, dw, None


dense.defvjp(_dense_fwd, _dense_bwd)


# ---------------------------------------------------------------------------
# Pre-packed weights: the quantize-once serving entry point
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def dense_packed(x: jax.Array, pw: PackedWeight, cfg: QuantConfig,
                 key: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ packed weight (K, N) -> (..., N) via the packed kernel.

    ``pw`` is produced once by ``pack_abfp_weight`` (or ``pack_model_params``
    over a whole model); every call skips the weight max/round/clip work the
    plain kernel redoes per grid step.
    """
    return abfp_matmul_packed_pallas(x, pw, cfg, _key_to_seed(key))


def _dense_packed_fwd(x, pw, cfg, key):
    return dense_packed(x, pw, cfg, key), (x, pw)


def _dense_packed_bwd(cfg, res, g):
    # STE (Eq. 8) against the dequantized lattice; packed leaves are frozen.
    x, pw = res
    g32 = g.astype(jnp.float32)
    w = dequantize_packed(pw)                                # (K, N) f32
    dx = jnp.matmul(g32, w.T).astype(x.dtype)
    zero_codes = np.zeros(pw.codes.shape, dtype=jax.dtypes.float0)
    dpw = PackedWeight(zero_codes, jnp.zeros_like(pw.scales),
                       pw.k, pw.n_cols, pw.tile_width, pw.bits_w,
                       gains=None if pw.gains is None
                       else jnp.zeros_like(pw.gains))
    return dx, dpw, None


dense_packed.defvjp(_dense_packed_fwd, _dense_packed_bwd)


# ---------------------------------------------------------------------------
# Tensor-parallel dispatch: shard_map over the 'model' mesh axis
# ---------------------------------------------------------------------------
#
# Serving shards every dense matmul COLUMN-parallel (output features over
# 'model'): each shard runs the kernel on its slice of the weight columns
# and the results are all-gathered.  Column splits never break ABFP K-tiles
# (tiles live along the contracting dim), every output element's f32
# contraction is computed exactly as on one device, and the Pallas noise
# salts are globalized via ``col_block_offset``/``num_col_blocks`` — so
# column-parallel execution is BIT-IDENTICAL to single-device at any shard
# count, which is what makes sharded serving testable against the
# single-device engine (tests/test_sharded_serving.py).
#
# ``dense_tp_row`` is the complementary ROW-parallel (contracting-dim)
# form: x columns and weight rows sharded, partial products combined with a
# psum over 'model'.  The psum changes f32 accumulation order, so it is
# reproducible but NOT bit-identical to single-device — serving therefore
# never routes through it (the ABFP spec rules demote K-sharding anyway:
# distributed.sharding.abfp_param_spec_tree); it exists for float-mode
# training shards.
#
# Both wrappers are forward-only (the serving engine never differentiates);
# QAT keeps using ``dense``/``dense_packed``.

_MODEL_AXIS = "model"       # mirrors distributed.sharding.MODEL_AXIS
_DATA_AXES = ("pod", "data")
_LANE = 128                 # packed-weight lane alignment (core.abfp)


def tp_size(mesh) -> int:
    """Size of the 'model' axis of ``mesh`` (1 when absent / no mesh)."""
    if mesh is None or _MODEL_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[_MODEL_AXIS]


def tp_col_quantum(cfg: QuantConfig, packed: bool, tp: int) -> Optional[int]:
    """Column-count divisor a weight needs for column-sharding over ``tp``
    shards, or None when the mode can never shard.

    THE single source of the shardability rule — placement
    (``distributed.sharding.serving_param_spec_tree``) and dispatch
    (``tp_shardable``) both consult it, so a weight is stored sharded
    exactly when the matmul will consume it sharded:

    * float weights: any even column split (``tp``);
    * kernel modes with noise: every local slice must be a whole number of
      128-lane column blocks (``tp * 128``), so local Pallas grids tile
      exactly like the global grid and the globalized salts line up;
    * kernel modes without noise: any even split — per-column values are
      block-layout independent;
    * the pure-jnp scan path (``abfp_ref``) draws noise with
      shape-dependent ``jax.random`` streams that cannot be
      column-globalized — never sharded.
    """
    if packed or cfg.mode in ("abfp_kernel", "abfp_packed", "abfp_fused"):
        return tp * _LANE if cfg.noise_lsb > 0.0 else tp
    if cfg.mode == "float":
        return tp
    return None     # abfp_ref


def tp_shardable(w, cfg: QuantConfig, mesh) -> bool:
    """Can ``w`` be column-sharded over 'model' with bit-identical results?
    Only 2-D weights qualify (leading batch axes are indexed/scanned
    first); the column rule lives in ``tp_col_quantum``."""
    tp = tp_size(mesh)
    if tp <= 1 or getattr(w, "ndim", 0) != 2:
        return False
    packed = isinstance(w, PackedWeight)
    quantum = tp_col_quantum(cfg, packed, tp)
    if quantum is None:
        return False
    cols = w.n_padded if packed else w.shape[-1]
    return cols % quantum == 0


def dense_tp(x: jax.Array, w, cfg: QuantConfig,
             key: Optional[jax.Array] = None, mesh=None) -> jax.Array:
    """Column-parallel ``dense``/``dense_packed`` over the 'model' axis.

    Bit-identical to the single-device call (see module comment).  Falls
    back to the single-device path when the weight is not shardable at this
    mesh (indivisible columns, abfp_ref mode, stacked weights) — the
    fallback runs replicated under GSPMD, still correct at any mesh shape.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if not tp_shardable(w, cfg, mesh):
        if isinstance(w, PackedWeight):
            return dense_packed(x, w, cfg, key)
        return dense(x, w, cfg, key)

    tp = tp_size(mesh)
    seed = _key_to_seed(key)
    packed = isinstance(w, PackedWeight)
    mode = "packed" if packed else cfg.mode

    # Activation batch axis: shard over the data axes when possible, so a
    # dp > 1 mesh parallelizes rows instead of redundantly recomputing the
    # full batch per data group.  Row splits are bit-identity-safe only
    # while noise is OFF: the noise lattice indexes rows block-locally, so
    # a batch split would re-seat rows and change their draws (columns are
    # globalized via the salt offset; rows are not).  With noise on, x
    # stays replicated — correctness over dp-throughput.
    daxes = tuple(a for a in _DATA_AXES
                  if mesh is not None and a in mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    batch_sharded = (cfg.noise_lsb == 0.0 and dp > 1 and x.ndim >= 2
                     and x.shape[0] % dp == 0)
    rep_x = (P(daxes, *([None] * (x.ndim - 1))) if batch_sharded
             else P(*([None] * x.ndim)))

    if mode == "packed":
        cols, n_cols = w.n_padded, w.n_cols
        nj_global, local_blocks = cols // _LANE, cols // tp // _LANE
    elif mode != "float":
        cols = n_cols = w.shape[-1]
        nj_global, local_blocks = cols // _LANE, cols // tp // _LANE

    def gather(y):
        return jax.lax.all_gather(y, _MODEL_AXIS, axis=-1, tiled=True)

    def offset():
        return jax.lax.axis_index(_MODEL_AXIS) * local_blocks

    if mode == "float":
        def body(x_, w_):
            return gather(jnp.matmul(x_, w_.astype(x_.dtype)))
        args, specs = (x, w), (rep_x, P(None, _MODEL_AXIS))
    elif mode == "packed":
        has_g = w.gains is not None

        def body(x_, codes, scales, *rest):
            # Per-tile gains live on the (replicated) K axis, so every
            # column shard amplifies with the same gain vector.
            gains = rest[0] if has_g else None
            s = rest[1:] if has_g else rest
            pw_l = PackedWeight(codes, scales, w.k, codes.shape[-1],
                                w.tile_width, w.bits_w, gains=gains)
            return gather(abfp_matmul_packed_pallas(
                x_, pw_l, cfg, s[0] if s else None,
                col_block_offset=offset(), num_col_blocks=nj_global))
        args = (x, w.codes, w.scales) \
            + ((w.gains,) if has_g else ()) \
            + (() if seed is None else (seed,))
        specs = (rep_x, P(None, _MODEL_AXIS), P(None, _MODEL_AXIS)) \
            + ((P(None),) if has_g else ()) \
            + (() if seed is None else (P(),))
    else:   # abfp_kernel
        def body(x_, w_, *s):
            return gather(abfp_matmul_pallas(
                x_, w_, cfg, s[0] if s else None,
                col_block_offset=offset(), num_col_blocks=nj_global))
        args = (x, w) + (() if seed is None else (seed,))
        specs = (rep_x, P(None, _MODEL_AXIS)) \
            + (() if seed is None else (P(),))

    out = shard_map(body, mesh=mesh, in_specs=specs,
                    out_specs=rep_x, check_rep=False)(*args)
    return out[..., :n_cols] if mode == "packed" else out


def dense_tp_row(x: jax.Array, w: jax.Array, cfg: QuantConfig,
                 mesh=None) -> jax.Array:
    """Row-parallel float matmul: contracting dim sharded over 'model',
    partials combined with a psum.  Reproducible, but NOT bit-identical to
    single-device (psum reorders the f32 reduction) — float mode only."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if cfg.mode != "float":
        raise ValueError(
            "dense_tp_row is float-only: sharding the contracting dim "
            "splits ABFP tile accumulation across devices, breaking the "
            "per-tile ADC semantics (use column-parallel dense_tp)")
    tp = tp_size(mesh)
    if tp <= 1 or w.shape[0] % tp != 0:
        return dense(x, w, cfg, None)

    x_spec = P(*([None] * (x.ndim - 1) + [_MODEL_AXIS]))

    def body(x_, w_):
        return jax.lax.psum(jnp.matmul(x_, w_.astype(x_.dtype)), _MODEL_AXIS)

    return shard_map(body, mesh=mesh,
                     in_specs=(x_spec, P(_MODEL_AXIS, None)),
                     out_specs=P(*([None] * x.ndim)),
                     check_rep=False)(x, w)
