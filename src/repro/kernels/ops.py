"""Unified dense-matmul dispatch: the single entry point models use.

``dense(x, w, cfg, key)`` routes to:
  * ``mode="float"``       — plain matmul in the operand dtype (FLOAT baseline)
  * ``mode="abfp_ref"``    — pure-jnp scan ABFP (core.abfp.abfp_matmul)
  * ``mode="abfp_kernel"`` — fused Pallas kernel (abfp_matmul_pallas)

All ABFP modes carry the straight-through estimator (paper Eq. 8): the
backward pass is that of the plain matmul, accumulated in FLOAT32 — this is
what makes the same call usable for inference simulation AND for QAT.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.abfp import QuantConfig, abfp_matmul
from repro.kernels.abfp_matmul import abfp_matmul_pallas


def _key_to_seed(key: Optional[jax.Array]) -> Optional[jax.Array]:
    """Fold a jax PRNG key into the int32 seed the Pallas hash PRNG expects."""
    if key is None:
        return None
    data = jax.random.key_data(key).astype(jnp.uint32)
    return jnp.bitwise_xor(data[..., 0], data[..., -1]).astype(jnp.int32)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def dense(x: jax.Array, w: jax.Array, cfg: QuantConfig,
          key: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ w (K, N) -> (..., N) under the QuantConfig's mode."""
    return _dense_fwd_impl(x, w, cfg, key)


def _dense_fwd_impl(x, w, cfg, key):
    if cfg.mode == "float":
        return jnp.matmul(x, w.astype(x.dtype))
    if cfg.mode == "abfp_ref":
        return abfp_matmul(x, w, cfg, key)
    if cfg.mode == "abfp_kernel":
        return abfp_matmul_pallas(x, w, cfg, _key_to_seed(key))
    raise ValueError(f"unknown quant mode: {cfg.mode!r}")


def _dense_fwd(x, w, cfg, key):
    return _dense_fwd_impl(x, w, cfg, key), (x, w)


def _dense_bwd(cfg, res, g):
    # STE (Eq. 8): gradients of the un-quantized matmul, FLOAT32 accumulation.
    x, w = res
    g32 = g.astype(jnp.float32)
    dx = jnp.matmul(g32, w.astype(jnp.float32).T).astype(x.dtype)
    g2 = g32.reshape(-1, g32.shape[-1])
    x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    dw = jnp.matmul(x2.T, g2).astype(w.dtype)
    return dx, dw, None


dense.defvjp(_dense_fwd, _dense_bwd)
