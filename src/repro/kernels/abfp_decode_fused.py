"""Fused ABFP decode-step kernels: QKV projections + quantized-KV attention.

The serving decode hot path was a CHAIN of dispatches per attention block —
three separate ``abfp_matmul_packed_pallas`` launches for the Q/K/V
projections, a jnp attention over the int8 KV cache, and a fourth launch for
the output projection.  This module fuses the chain's front end into two
Pallas kernels:

``fused_qkv_packed_pallas``
    ONE weight-stationary launch over the three packed projection weights.
    Following Drumond et al.'s hybrid-BFP dot-product tiling (PAPERS.md:
    "Training DNNs with Hybrid Block Floating Point"), the weights stay
    resident over the tile grid while the (tiny, m = batch) decode
    activation block streams against them: the kernel concatenates the
    lane-aligned column blocks of wq|wk|wv into one logical weight and runs
    the SAME grid cells the three separate launches would run — same block
    sizes, same ``_abfp_contrib`` core, same noise salts (re-derived
    per-segment via the explicit ``idx`` coordinates) — so the fused output
    is bit-identical to the separate calls BY CONSTRUCTION, while paying one
    kernel launch instead of three.

``fused_quantized_decode_attention``
    A (B,)-grid Pallas kernel computing decode attention directly on the
    int8 KV codes, mirroring ``models.layers.quantized_decode_attention``
    op-for-op.  A decode tick has a single query row, so the online-softmax
    running max / denominator of ``flash_attention.py`` collapses to one
    masked softmax over the whole (cache-resident) key axis; the kernel
    keeps that degenerate form explicit so the scores/PV contractions and
    the masking constant match the jnp reference bit-for-bit.

Gain / amplification (the paper's headline knob) rides along: packed
weights carry per-tile ADC gains (``PackedWeight.gains``, derived by
``core.abfp.adaptive_tile_gains``) and the shared ``_abfp_contrib`` core
amplifies each tile's partial product before the output quantizer and
divides it back out of the Eq. 6 sum — see ``core/abfp.py`` and
``docs/NUMERICS.md`` for the exact equations.

Tensor-parallel dispatch (``fused_qkv_dense``) mirrors ``kernels.ops
.dense_tp``: the three weights column-shard over the 'model' axis, each
shard runs the fused kernel on its local column blocks with per-segment
globalized noise salts, and the outputs are all-gathered — bit-identical to
single-device at any (dp, tp) mesh shape (tests/test_sharded_serving.py).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.abfp import PackedWeight, QuantConfig, code_dtype
from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.abfp_matmul import (
    DEFAULT_BN,
    _abfp_contrib,
    _ceil_to,
    _seed_smem,
    auto_bm,
    default_bk,
)

_MODEL_AXIS = "model"       # mirrors kernels.ops._MODEL_AXIS


# ---------------------------------------------------------------------------
# Fused QKV projection kernel
# ---------------------------------------------------------------------------


def _fused_qkv_kernel(
    seed_ref,  # SMEM (3, 2) int32: [seed, col-block offset] per segment
    x_ref,     # VMEM (bm, bk) f32
    wc_ref,    # VMEM (bk, bn) int8 codes (concatenated segments)
    sw_ref,    # VMEM (tk, bn) scales
    *refs,     # [g_ref (tk, 1) f32 gains]  o_ref (bm, bn)  acc_ref scratch
    cfg: QuantConfig,
    tk: int,
    n: int,
    seg_starts: Tuple[int, int, int],
    seg_nj: Tuple[int, int, int],
    has_gains: bool,
):
    """Fused-QKV kernel body.

    Identical to ``_abfp_matmul_packed_kernel`` except that the column-block
    axis spans three weight segments: the body resolves which segment this
    grid step belongs to (static boundaries ``seg_starts``) and hands
    ``_abfp_contrib`` the segment's OWN coordinates — its seed, its global
    column-block count ``seg_nj[s]`` and its local block index (plus the
    tensor-parallel offset) — so every noise draw matches the draw the
    stand-alone packed kernel makes for that (weight, block).
    """
    if has_gains:
        g_ref, o_ref, acc_ref = refs
        g = g_ref[...].astype(jnp.float32).reshape(tk)
    else:
        o_ref, acc_ref = refs
        g = None

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    bn = wc_ref.shape[1]

    xt = x_ref[...].astype(jnp.float32).reshape(bm, tk, n)
    cdt = code_dtype(max(cfg.bits_x, cfg.bits_w))
    wq = wc_ref[...].astype(cdt).reshape(tk, n, bn)
    sw = sw_ref[...].astype(jnp.float32)

    # Segment bookkeeping: scalar selects on the (static) boundaries.  The
    # per-segment SMEM rows carry [seed, tensor-parallel col-block offset].
    i = pl.program_id(0)
    jj = pl.program_id(1)
    in1 = (jj >= seg_starts[1])
    in2 = (jj >= seg_starts[2])

    def _sel(a0, a1, a2):
        return jnp.where(in2, a2, jnp.where(in1, a1, a0))

    seed_val = _sel(seed_ref[0, 0], seed_ref[1, 0], seed_ref[2, 0])
    off = _sel(seed_ref[0, 1], seed_ref[1, 1], seed_ref[2, 1])
    start = _sel(jnp.int32(seg_starts[0]), jnp.int32(seg_starts[1]),
                 jnp.int32(seg_starts[2]))
    nj_g = _sel(jnp.int32(seg_nj[0]), jnp.int32(seg_nj[1]),
                jnp.int32(seg_nj[2]))
    j_local = jj - start + off

    acc_ref[...] += _abfp_contrib(
        xt, wq, sw, seed_ref, cfg, tk, n, g=g,
        idx=(i, j_local, k, nk, nj_g, seed_val))

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _validate_fused_pws(pws, cfg: QuantConfig, bn: int) -> None:
    """Shared-shape validation for the three fused projection weights."""
    if len(pws) != 3:
        raise ValueError(f"fused QKV takes exactly 3 PackedWeights, "
                         f"got {len(pws)}")
    k_dim = pws[0].k
    n_gains = sum(pw.gains is not None for pw in pws)
    if n_gains not in (0, 3):
        raise ValueError("fused QKV weights must all carry gains or none")
    for pw in pws:
        if pw.codes.ndim != 2:
            raise ValueError(f"fused kernel takes 2-D PackedWeights, got "
                             f"codes {pw.codes.shape}")
        if pw.k != k_dim:
            raise ValueError(f"fused QKV weights must share K: "
                             f"{pw.k} != {k_dim}")
        if pw.tile_width != cfg.tile_width or pw.bits_w != cfg.bits_w:
            raise ValueError(
                f"PackedWeight(n={pw.tile_width}, bits_w={pw.bits_w}) does "
                f"not match cfg(n={cfg.tile_width}, bits_w={cfg.bits_w})")
        if pw.scales.dtype != jnp.dtype(cfg.scale_dtype):
            raise ValueError(
                f"PackedWeight scales are {pw.scales.dtype} but "
                f"cfg.scale_dtype is {jnp.dtype(cfg.scale_dtype)}")
        if pw.n_padded % bn != 0:
            raise ValueError(
                f"fused kernel needs every weight's padded columns to be a "
                f"multiple of bn={bn} (got {pw.n_padded}) so the segment "
                f"boundaries fall on block edges")
    if cfg.noise_lsb > 0.0 and bn % 128 != 0:
        raise ValueError(
            f"noise_lsb > 0 requires bn to be a multiple of 128 (got "
            f"bn={bn}): other widths change the per-weight grids vs the "
            f"stand-alone packed kernel and break noise bit-identity")


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "bm", "bn", "bk", "interpret", "num_col_blocks"),
)
def fused_qkv_packed_pallas(
    x: jax.Array,
    pws: Sequence[PackedWeight],
    cfg: QuantConfig,
    seeds: Optional[Sequence[Optional[jax.Array]]] = None,
    *,
    bm: Optional[int] = None,
    bn: int = DEFAULT_BN,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
    col_block_offsets: Optional[Sequence[jax.Array]] = None,
    num_col_blocks: Optional[Tuple[int, int, int]] = None,
):
    """Three packed ABFP projections of one activation in ONE Pallas launch.

    ``x``: (..., K); ``pws``: (wq, wk, wv) 2-D PackedWeights sharing K and
    the cfg's tile geometry; ``seeds``: one int32 noise seed per projection
    (each the seed the stand-alone call for that weight would receive), or
    None when ``cfg.noise_lsb == 0``.  Returns the tuple
    ``(x @ wq, x @ wk, x @ wv)`` with each output sliced to its weight's
    logical columns.

    Bit-identical to three ``abfp_matmul_packed_pallas`` calls at the same
    (bm, bn, bk): the fused grid is the disjoint union of the three
    per-weight grids (same defaults — ``bm = auto_bm(m)``,
    ``bk = default_bk(n, K)`` depend only on shared quantities) and each
    grid cell runs the identical ``_abfp_contrib`` block with the segment's
    own noise coordinates.  What changes is dispatch: one weight-stationary
    launch streaming all three weights instead of three launches re-staging
    the same activation block.

    ``col_block_offsets`` / ``num_col_blocks`` (one per segment): the
    tensor-parallel salt globalization of ``abfp_matmul_packed_pallas``,
    applied per weight — see ``fused_qkv_dense``.
    """
    pws = tuple(pws)
    _validate_fused_pws(pws, cfg, bn)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = cfg.tile_width
    k_dim = pws[0].k
    if x.shape[-1] != k_dim:
        raise ValueError(f"x K dim {x.shape[-1]} != packed weight K {k_dim}")
    if bk is None:
        bk = default_bk(n, k_dim)
    assert bk % n == 0, (bk, n)

    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, k_dim).astype(jnp.float32)
    m_dim = x2.shape[0]
    if bm is None:
        bm = auto_bm(m_dim)

    kp0 = pws[0].kp
    mp, kp = _ceil_to(m_dim, bm), _ceil_to(kp0, bk)
    x2 = jnp.pad(x2, ((0, mp - m_dim), (0, kp - k_dim)))

    # Concatenate the three weights' column blocks into one logical weight.
    # Each segment is already lane-aligned from pack time; K rows pad to the
    # shared kp exactly as the stand-alone wrapper pads them (code 0 under
    # scale 0: exact no-ops).
    has_gains = pws[0].gains is not None
    njs = tuple(pw.n_padded // bn for pw in pws)
    seg_starts = (0, njs[0], njs[0] + njs[1])
    seg_nj = tuple(num_col_blocks) if num_col_blocks is not None else njs
    nj_tot = sum(njs)
    tk = bk // n

    wcs, sws, gcols = [], [], []
    for pw, nj_s in zip(pws, njs):
        wc, sw = pw.codes, pw.scales
        if kp > kp0:
            wc = jnp.pad(wc, ((0, kp - kp0), (0, 0)))
            sw = jnp.pad(sw, ((0, (kp - kp0) // n), (0, 0)))
        wcs.append(wc)
        sws.append(sw)
        if has_gains:
            gp = jnp.pad(pw.gains.astype(jnp.float32),
                         (0, kp // n - pw.num_tiles), constant_values=1.0)
            gcols.append(jnp.repeat(gp[:, None], nj_s, axis=1))
    wc = jnp.concatenate(wcs, axis=1)                  # (kp, nj_tot * bn)
    sw = jnp.concatenate(sws, axis=1)                  # (kp/n, nj_tot * bn)

    if seeds is None:
        seeds = (None, None, None)
    offs = (col_block_offsets if col_block_offsets is not None
            else (None, None, None))
    seed = jnp.stack([_seed_smem(s, cfg.noise_lsb, o)
                      for s, o in zip(seeds, offs)])   # (3, 2) int32

    grid = (mp // bm, nj_tot, kp // bk)
    kernel = functools.partial(
        _fused_qkv_kernel, cfg=cfg, tk=tk, n=n,
        seg_starts=seg_starts, seg_nj=seg_nj, has_gains=has_gains)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),                 # seeds
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),        # x
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),        # codes
        pl.BlockSpec((tk, bn), lambda i, j, k: (k, j)),        # scales
    ]
    inputs = [seed, x2, wc, sw]
    if has_gains:
        # Per-(tile, column-block) gains: column j of the (T, nj_tot) table
        # is the owning segment's per-tile gain vector, so each grid cell
        # reads its own segment's gains with the same (tk, 1) block the
        # stand-alone packed kernel uses.
        gcol = jnp.concatenate(gcols, axis=1)          # (kp/n, nj_tot)
        in_specs.append(pl.BlockSpec((tk, 1), lambda i, j, k: (k, j)))
        inputs.append(gcol)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, nj_tot * bn), cfg.out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*inputs)

    outs = []
    col = 0
    for pw, nj_s in zip(pws, njs):
        seg = out[:m_dim, col:col + pw.n_cols]
        outs.append(seg.reshape(*batch_shape, pw.n_cols))
        col += nj_s * bn
    return tuple(outs)


# ---------------------------------------------------------------------------
# Fused quantized-KV decode attention
# ---------------------------------------------------------------------------


def _fused_attn_kernel(len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                       o_ref):
    """Per-batch-element decode attention on int8 KV codes.

    Mirrors ``models.layers.quantized_decode_attention`` op-for-op for one
    batch element: scores contract head_dim against the raw int8 codes, the
    per-position scales factor out of both contractions, masked positions
    get the same -1e30 the jnp path uses, and the single query row makes
    the flash-attention online softmax (``flash_attention.py``) degenerate
    to one ``jax.nn.softmax`` over the key axis.
    """
    b = pl.program_id(0)
    h, d = q_ref.shape[-2], q_ref.shape[-1]
    s_max, kh = kc_ref.shape[1], kc_ref.shape[2]
    rep = h // kh

    qf = q_ref[0, 0].astype(jnp.float32) * (d ** -0.5)          # (h, d)
    qg = qf.reshape(kh, rep, d)
    kc = kc_ref[0].astype(jnp.float32)                          # (s, kh, d)
    # scores: einsum "grd,sgd->grs" (batch kh, contract d)
    s = jax.lax.dot_general(
        qg, kc, dimension_numbers=(((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)                     # (kh, rep, s)
    s = s * (ks_ref[0].astype(jnp.float32).T[:, None, :] / 127.0)
    pos = jax.lax.broadcasted_iota(jnp.int32, (kh, rep, s_max), 2)
    s = jnp.where(pos < len_ref[b], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)                              # (kh, rep, s)
    pv = p * (vs_ref[0].astype(jnp.float32).T[:, None, :] / 127.0)
    # PV: einsum "grs,sgd->grd" (batch kh, contract s)
    out = jax.lax.dot_general(
        pv, vc_ref[0].astype(jnp.float32),
        dimension_numbers=(((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)                     # (kh, rep, d)
    o_ref[0, 0] = out.reshape(h, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_quantized_decode_attention(
    q: jax.Array,
    k_codes: jax.Array, k_scale: jax.Array,
    v_codes: jax.Array, v_scale: jax.Array,
    *,
    lengths: jax.Array,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Pallas decode attention over the int8 KV cache, one grid cell per
    batch element.

    Same signature and bit-identical output as
    ``models.layers.quantized_decode_attention`` (enforced by
    tests/test_fused.py); the cache is read once as int8 blocks instead of
    traversing XLA's intermediate materializations of the batched einsum
    chain.  ``q``: (B, 1, H, D); codes: (B, S, KH, D) int8; scales:
    (B, S, KH); ``lengths``: (B,) int32 filled-slot counts.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, _, h, d = q.shape
    s_max, kh = k_codes.shape[1], k_codes.shape[2]
    return pl.pallas_call(
        _fused_attn_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # lengths
            pl.BlockSpec((1, 1, h, d), lambda i: (i, 0, 0, 0)),   # q
            pl.BlockSpec((1, s_max, kh, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s_max, kh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_max, kh, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s_max, kh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, d), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_codes, k_scale, v_codes, v_scale)


# ---------------------------------------------------------------------------
# Dispatch: single-device / tensor-parallel fused QKV
# ---------------------------------------------------------------------------


def fused_qkv_dense(x, pws, cfg: QuantConfig, keys, mesh=None):
    """Numerics-level dispatch for the fused QKV projection.

    ``keys``: one jax PRNG key (or None) per projection — EXACTLY the keys
    the three consecutive ``Numerics.dense`` calls of the packed chain
    would fold (models/layers.py threads them); they become the kernel's
    per-segment noise seeds.  Routing mirrors ``kernels.ops.dense_tp``:

    * no mesh / tp == 1 — one fused launch;
    * tp > 1 and all three weights column-shardable — shard_map over
      'model': each shard fuses its LOCAL column blocks of all three
      weights with per-segment globalized salts, then all-gathers each
      output (bit-identical to single-device, as for ``dense_tp``);
    * otherwise — per-weight ``dense_tp`` (the packed chain's own dispatch,
      with its replicated fallback), keeping fused mode correct at every
      mesh shape.
    """
    from repro.kernels.ops import _key_to_seed, dense_tp, tp_shardable, tp_size

    tp = tp_size(mesh)
    if tp > 1:
        if all(tp_shardable(pw, cfg, mesh) for pw in pws):
            return _fused_qkv_tp(x, pws, cfg,
                                 [_key_to_seed(k) for k in keys], mesh)
        return tuple(dense_tp(x, pw, cfg, key, mesh)
                     for pw, key in zip(pws, keys))
    return fused_qkv_packed_pallas(
        x, pws, cfg, [_key_to_seed(k) for k in keys])


def _fused_qkv_tp(x, pws, cfg: QuantConfig, seeds, mesh):
    """Column-parallel fused QKV over the 'model' axis (see
    ``fused_qkv_dense``); weights arrive column-sharded, gains and seeds
    replicated, x replicated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.ops import tp_size

    tp = tp_size(mesh)
    pws = tuple(pws)
    njs_g = tuple(pw.n_padded // DEFAULT_BN for pw in pws)
    local_blocks = tuple(nj // tp for nj in njs_g)
    has_gains = pws[0].gains is not None
    has_seed = seeds[0] is not None
    rep_x = P(*([None] * x.ndim))

    def body(x_, cq, sq, ck, sk, cv, sv, *rest):
        gains = rest[:3] if has_gains else (None, None, None)
        sds = rest[3:] if has_gains else rest
        t = jax.lax.axis_index(_MODEL_AXIS)
        pws_l = tuple(
            PackedWeight(c, s_, pw.k, c.shape[-1], pw.tile_width, pw.bits_w,
                         gains=g)
            for c, s_, g, pw in zip((cq, ck, cv), (sq, sk, sv), gains, pws))
        outs = fused_qkv_packed_pallas(
            x_, pws_l, cfg, tuple(sds) if has_seed else None,
            col_block_offsets=tuple(t * lb for lb in local_blocks),
            num_col_blocks=njs_g)
        return tuple(jax.lax.all_gather(y, _MODEL_AXIS, axis=-1, tiled=True)
                     for y in outs)

    args = [x]
    specs = [rep_x]
    for pw in pws:
        args += [pw.codes, pw.scales]
        specs += [P(None, _MODEL_AXIS), P(None, _MODEL_AXIS)]
    if has_gains:
        args += [pw.gains for pw in pws]
        specs += [P(None)] * 3
    if has_seed:
        args += list(seeds)
        specs += [P()] * 3

    out = shard_map(body, mesh=mesh, in_specs=tuple(specs),
                    out_specs=(rep_x,) * 3, check_rep=False)(*args)
    return tuple(y[..., :pw.n_cols] for y, pw in zip(out, pws))
