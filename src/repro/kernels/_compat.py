"""jax version compatibility shims shared by the Pallas kernels."""

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
