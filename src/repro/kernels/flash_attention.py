"""Fused flash-attention (forward) Pallas TPU kernel.

The roofline analysis (EXPERIMENTS.md §Roofline) shows attention-score
traffic — O(S²) HBM bytes — dominating every train/prefill memory term in
the kernel-less XLA lowering.  This kernel keeps the (bq, bk) score tile,
the online-softmax statistics and the output accumulator in VMEM: HBM
traffic falls to one read of Q/K/V + one write of O.

Supports causal and sliding-window masking and GQA (kv-head mapping via the
BlockSpec index map — no materialized head repetition).  Fully-masked KV
blocks are skipped with ``pl.when`` (the causal wedge does half the work).

Forward-only by design: training uses the q-chunked remat path
(``models.layers.train_attention``); serving prefill is where the S² memory
term bites (32k cells) and where this kernel applies.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BQ = 512
DEFAULT_BK = 512
_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, causal, window, bq, bk, skv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # Skip KV blocks that are entirely masked (future of the causal wedge /
    # beyond the sliding window).
    live = jnp.bool_(True)
    if causal:
        live = k_start <= q_start + bq - 1
    if window > 0:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < skv                                # kv padding
        if causal:
            valid = jnp.logical_and(valid, kpos <= qpos)
        if window > 0:
            valid = jnp.logical_and(valid, kpos > qpos - window)
        s = jnp.where(valid, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        den = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


def _ceil_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KH, D) with H % KH == 0 (GQA).

    Returns (B, Sq, H, D) in q.dtype.  Softmax statistics in f32.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    rep = h // kh

    bq = min(bq, _ceil_to(sq, 128))
    bk = min(bk, _ceil_to(skv, 128))
    sqp, skvp = _ceil_to(sq, bq), _ceil_to(skv, bk)

    # (B*H, S, D) layout; KV heads addressed through the index map (GQA).
    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * kh, skv, d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * kh, skv, d)
    if sqp != sq:
        qt = jnp.pad(qt, ((0, 0), (0, sqp - sq), (0, 0)))
    if skvp != skv:
        kt = jnp.pad(kt, ((0, 0), (0, skvp - skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, skvp - skv), (0, 0)))

    grid = (b * h, sqp // bq, skvp // bk)

    def kv_map(bh, qi, ki):
        return (bh // h) * kh + (bh % h) // rep, ki, 0

    kernel = functools.partial(
        _flash_kernel, scale=d ** -0.5, causal=causal, window=window,
        bq=bq, bk=bk, skv=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)

    out = out[:, :sq].reshape(b, h, sq, d)
    return jnp.moveaxis(out, 1, 2)
