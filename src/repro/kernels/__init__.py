"""repro.kernels — fused ABFP matmul (Pallas TPU) + dispatch + oracle.

The ABFP tiled matmul is the paper's compute hot-spot: simulating the
per-tile ADC in plain XLA materializes a (K/n, M, N) partial-product tensor.
The Pallas kernel fuses scale/quantize/dot/ADC/accumulate in VMEM.
"""

from repro.kernels.abfp_matmul import (  # noqa: F401
    abfp_matmul_packed_pallas,
    abfp_matmul_pallas,
)
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.ops import dense, dense_packed  # noqa: F401
from repro.kernels.ref import abfp_matmul_ref  # noqa: F401
