"""Pure-jnp oracle for the ABFP matmul kernel.

Independent of ``repro.core.abfp``'s scan implementation: materializes the
full (T, M, N) partial-product tensor with one einsum, applies the ADC model,
and contracts against the scales.  Only suitable for test-sized shapes; the
production paths are ``core.abfp.abfp_matmul`` (scan) and the fused Pallas
kernel (``abfp_matmul.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.abfp import (
    QuantConfig,
    adc,
    quantize_input_tiles,
    quantize_weight_tiles,
)


def abfp_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    cfg: QuantConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Oracle ABFP matmul: x (..., K) @ w (K, N) -> (..., N)."""
    if key is None and cfg.noise_lsb > 0.0:
        raise ValueError("noise_lsb > 0 requires a PRNG key")

    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])

    x_q, s_x = quantize_input_tiles(x2, cfg)   # (M, T, n) codes, (M, T)
    w_q, s_w = quantize_weight_tiles(w, cfg)   # (T, n, N) codes, (T, N)
    t = w_q.shape[0]
    m = x2.shape[0]
    n_out = w.shape[1]

    # Exact integer partial dot products (the analog MAC array output).
    p = jnp.einsum(
        "mtn,tno->tmo", x_q, w_q, preferred_element_type=jnp.float32
    )  # (T, M, N)

    if cfg.noise_lsb > 0.0:
        keys = jax.random.split(key, t)
        e = jax.vmap(
            lambda k: jax.random.uniform(
                k, (m, n_out), jnp.float32,
                minval=-cfg.noise_lsb, maxval=cfg.noise_lsb)
        )(keys)
    else:
        e = None

    y_q = adc(p, cfg, e) * jnp.float32(cfg.bin_y)          # ADC (Eq. 7)

    # Eq. 6: rescale by s_x * s_w / G, accumulate in FLOAT32.
    y = jnp.einsum("tmo,mt,to->mo", y_q, s_x, s_w) / jnp.float32(cfg.gain)
    return y.reshape(*batch_shape, n_out).astype(cfg.out_dtype)
