"""Fused ABFP tiled-matmul Pallas TPU kernel.

The compute hot-spot of ABFP simulation.  A naive XLA implementation either
materializes the (T, M, N) per-tile partial-product tensor in HBM (T = K/n —
a 64x blow-up at K=8192, n=128; 512x at n=8) or re-reads the operands T
times.  This kernel keeps everything tile-local in VMEM:

  grid = (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics).
  Each step loads x_blk (bm, bk) and w_blk (bk, bn), splits the K block into
  tk = bk/n ABFP tiles, and per tile:

    s_x = max|x_tile|  (bf16-rounded)          s_w = max|w_tile|
    x_q = Q(x/s_x; d_X, 1)                     w_q = Q(w/s_w; d_W, 1)
    p   = x_q . w_q                 (MXU batched dot over the tk tiles)
    y_q = Q(G*p + E; n*d_Y, n)      (ADC with gain and uniform noise)
    acc += y_q * s_x * s_w / G      (FLOAT32 accumulator in VMEM scratch)

  The accumulator is written to HBM once, as BFLOAT16, on the last K step.

AMS noise uses a counter-based murmur3-style hash PRNG (seed, program ids,
tile index) -> uniform, identical under `interpret=True` on CPU and compiled
TPU execution, so the oracle comparison and noise statistics are testable in
this container.

TPU adaptation note (DESIGN.md §2): the paper's analog device processes one
n-wide tile per clock; here tk tiles are batched into one MXU dot_general so
small n (8/32) still feeds the 128x128 systolic array efficiently — the tile
*semantics* (per-tile ADC quantization) are preserved exactly.

Packed-weight variant (``abfp_matmul_packed_pallas``)
-----------------------------------------------------

The kernel above re-derives the weight scales and integer codes on every
grid step — M/bm times per call, and once per decode tick in serving — even
though weights are static.  The packed variant consumes a pre-quantized
``repro.core.abfp.PackedWeight`` instead:

  codes : int8     (Kp, Np)  integer weight codes in [-L_w, +L_w]; row
                             ``t*n + i`` is element i of K-tile t.  Kp is K
                             zero-padded to a multiple of the tile width n;
                             Np is N zero-padded to the 128-lane boundary
                             at PACK time (padding rows/columns are code 0
                             under scale 0, contributing exactly 0).
  scales: bf16 (T, Np)       per-(tile, out-column) scales, T = Kp/n,
                             ``cfg.scale_dtype``-rounded (bf16 by default)
                             exactly as the in-kernel ``max|w| -> bf16``
                             derivation would round them.

Padding contract: the wrapper zero-pads Kp -> multiple of bk and
Np -> multiple of bn at call time and slices the output back to the
caller's logical (M, N); with the default (or any 128-multiple) bn these
pads are no-ops, so the hot path streams codes/scales exactly as stored
— no per-call weight re-materialization.  Max-abs scales only
(``scale_percentile`` configs are rejected at pack time).

Per grid step the packed kernel loads the int8 code block + bf16 scale block
straight from HBM, casts, and goes directly to the MXU dot — deleting the
per-step weight max/round/clip work and halving weight-side HBM bytes
(int8 codes vs bf16, plus T/K-sized scales).  Output is bit-identical to
``abfp_matmul_pallas`` at matching block sizes — same integer lattice
(pack-time scales are bf16-rounded exactly as in-kernel), same f32 ADC
constant, same noise hash and salt layout, same accumulation order — and
matches the einsum oracle to the usual f32 accumulation-order ULP
tolerance (the oracle contracts all T tiles in one einsum).

Decode-shape specialization: when ``bm`` is not given, both wrappers pick
``bm = min(DEFAULT_BM, ceil8(M))`` so a 1–8 row decode matmul runs an
(8, bk) activation block instead of being zero-padded to 128 rows — a 16x
cut in per-step activation work at M=1.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.abfp import PackedWeight, QuantConfig
from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BM = 128
DEFAULT_BN = 128


def auto_bm(m: int) -> int:
    """Decode-shape specialization: smallest f32-legal row block covering m.

    A decode step has m in 1..8; padding it to the 128-row default block
    wastes 16x the activation-side work (and VMEM).  f32 sublane tiling
    needs multiples of 8, so clamp to [8, DEFAULT_BM].
    """
    return min(DEFAULT_BM, max(8, ((m + 7) // 8) * 8))


def default_bk(n: int, k: int) -> int:
    """K-block: multiple of the ABFP tile width, capped to bound VMEM.

    tk = bk/n partial products of (bm, bn) f32 live in VMEM: at bm=bn=128,
    bk=512 -> tk*64KiB <= 4 MiB (n=8 uses bk=256 -> 2 MiB).
    """
    cap = 256 if n <= 8 else 512
    bk = min(cap, max(n, k))
    return max(n, (bk // n) * n)


# ---------------------------------------------------------------------------
# Counter-based uniform PRNG (murmur3 finalizer lattice hash)
# ---------------------------------------------------------------------------


def _hash_uniform(shape, seed, salt):
    """Deterministic uniform [0, 1) lattice: hash(row, col, seed, salt)."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    x = (
        r * jnp.uint32(0x9E3779B9)
        + c * jnp.uint32(0x85EBCA6B)
        + seed.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35)
        + salt.astype(jnp.uint32) * jnp.uint32(0x27D4EB2F)
    )
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) / jnp.float32(1 << 24)


# ---------------------------------------------------------------------------
# Kernel bodies (shared ABFP core; weight source is the only difference)
# ---------------------------------------------------------------------------


def _abfp_contrib(xt, wq, sw, seed_ref, cfg: QuantConfig, tk: int, n: int,
                  nj: Optional[int] = None, g=None, idx=None):
    """Shared per-grid-step ABFP math: everything except how (wq, sw) were
    obtained.  ALL the ABFP kernels (unpacked, packed, fused decode) route
    through this one function so the packed == unpacked == fused
    bit-identity contract lives in exactly one place.

    xt: (bm, tk, n) f32 activation tiles;  wq: (tk, n, bn) integer weight
    codes, already cast to the MXU code dtype;  sw: (tk, bn) f32 weight
    scales (``scale_dtype``-rounded).  Returns the (bm, bn) f32 contribution
    of this K block.

    ``seed_ref`` is SMEM (2,) int32: [noise seed, column-block offset].  The
    offset (plus ``nj``, the GLOBAL column-block count) globalizes the noise
    salt for tensor-parallel column shards: shard s computing column blocks
    [off, off + nj_local) draws exactly the noise the single-device grid
    draws for those blocks, so sharded execution is bit-identical to
    unsharded at any shard count (kernels/ops.dense_tp).  Defaults (offset
    0, nj = num_programs(1)) reproduce the historical single-device salts.

    ``g`` (optional (tk,) f32): per-tile ADC gains (``PackedWeight.gains``,
    the paper's amplification knob).  Each tile's exact partial product is
    amplified by G_t before the b_Y-bit output quantizer
    (``v = p * adc_base_scale * G_t``) and divided back out of that tile's
    Eq. 6 term (``yq * s_x * s_w / G_t``) — raising effective output
    precision by log2(G_t) bits with no extra output bits.  ``None`` keeps
    the scalar ``cfg.gain`` path byte-for-byte unchanged; an all-ones ``g``
    is bit-identical to the scalar path at ``gain=1.0`` (amplifying and
    dividing by exactly 1.0 are exact f32 no-ops).

    ``idx`` (optional): explicit grid coordinates
    ``(i, j, k, nk, nj_g, seed_val)`` replacing the ``pl.program_id`` /
    ``seed_ref`` reads — the fused decode kernel spans several logical
    weights in one launch and must reproduce each segment's own
    single-weight salts, so it computes the per-segment coordinates itself
    and passes them here.  ``None`` (every single-weight kernel) reads the
    real grid position, preserving the historical salts exactly.
    """
    bm = xt.shape[0]
    bn = wq.shape[-1]

    # Adaptive per-tile activation scales (paper Sec. III) + DAC encode
    # (Eq. 2).  Activations are dynamic: their scales/codes must be derived
    # per call, unlike the static weight side.
    sx = jnp.max(jnp.abs(xt), axis=2)               # (bm, tk)
    sx = sx.astype(cfg.scale_dtype).astype(jnp.float32)
    sx_safe = jnp.where(sx == 0.0, 1.0, sx)
    lx = jnp.float32(2 ** (cfg.bits_x - 1) - 1)
    xq = jnp.clip(jnp.round(xt / sx_safe[:, :, None] * lx), -lx, lx)
    xq = xq.astype(wq.dtype)

    # Batched MXU dot over the tk tiles: (tk, bm, n) @ (tk, n, bn).
    # Integer-valued operands: the f32-accumulated dot is EXACT
    # (|p| <= n*L_x*L_w < 2^24 at 8 bits), matching the analog MAC array and
    # the jnp oracle bit-for-bit.
    p = jax.lax.dot_general(
        xq.transpose(1, 0, 2),
        wq,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                               # (tk, bm, bn)

    # Eq. 5/7: the ADC in code units — same fused f32 constant as the oracle
    # so round-half-even ties resolve identically.  Per-tile gains amplify
    # each tile's exact product before the output quantizer.
    if g is None:
        v = p * jnp.float32(cfg.adc_code_scale)
    else:
        v = p * jnp.float32(cfg.adc_base_scale) * g[:, None, None]
    if cfg.noise_lsb > 0.0:
        # One independent uniform noise draw per partial output, in LSB
        # units, salted by the grid position.
        if idx is None:
            i = pl.program_id(0)
            j = pl.program_id(1) + seed_ref[1]      # global column block
            k = pl.program_id(2)
            nk = pl.num_programs(2)
            nj_g = nj if nj is not None else pl.num_programs(1)
            seed_val = seed_ref[0]
        else:
            i, j, k, nk, nj_g, seed_val = idx
        salt = (i * nj_g + j) * nk + k
        u = _hash_uniform(
            (tk * bm, bn),
            seed_val,
            jnp.uint32(salt),
        ).reshape(tk, bm, bn)
        v = v + (u - 0.5) * jnp.float32(2.0 * cfg.noise_lsb)
    ly = jnp.float32(2 ** (cfg.bits_y - 1) - 1)
    yq = jnp.clip(jnp.round(v), -ly, ly) * jnp.float32(cfg.bin_y)

    # Eq. 6: rescale partials and sum over the tk tiles in FLOAT32 (per-tile
    # gains divide out inside the sum; the scalar gain after it).
    if g is None:
        return jnp.sum(
            yq * sx.T[:, :, None] * sw[:, None, :], axis=0
        ) / jnp.float32(cfg.gain)                    # (bm, bn)
    return jnp.sum(
        yq * sx.T[:, :, None] * sw[:, None, :] / g[:, None, None], axis=0
    )                                                # (bm, bn)


def _abfp_matmul_kernel(
    seed_ref,  # SMEM (2,) int32: [seed, col-block offset]
    x_ref,     # VMEM (bm, bk)
    w_ref,     # VMEM (bk, bn)
    o_ref,     # VMEM (bm, bn)
    acc_ref,   # VMEM scratch (bm, bn) f32
    *,
    cfg: QuantConfig,
    tk: int,
    n: int,
    nj: Optional[int] = None,
):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    bn = w_ref.shape[1]

    xt = x_ref[...].astype(jnp.float32).reshape(bm, tk, n)
    wt = w_ref[...].astype(jnp.float32).reshape(tk, n, bn)

    # Weight side, re-derived every grid step (the packed kernel skips
    # this): scale_dtype-rounded max-abs scales + DAC encode (Eq. 2).
    sw = jnp.max(jnp.abs(wt), axis=1)               # (tk, bn)
    sw = sw.astype(cfg.scale_dtype).astype(jnp.float32)
    sw_safe = jnp.where(sw == 0.0, 1.0, sw)
    lw = jnp.float32(2 ** (cfg.bits_w - 1) - 1)
    wq = jnp.clip(jnp.round(wt / sw_safe[:, None, :] * lw), -lw, lw)
    # bf16 codes are exact for <= 9-bit operands and feed the MXU at its
    # bf16 rate (vs ~1/8 rate for f32) — see core.abfp.code_dtype.
    from repro.core.abfp import code_dtype
    wq = wq.astype(code_dtype(max(cfg.bits_x, cfg.bits_w)))

    acc_ref[...] += _abfp_contrib(xt, wq, sw, seed_ref, cfg, tk, n, nj=nj)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Host-side wrapper
# ---------------------------------------------------------------------------


def _ceil_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _seed_smem(seed, noise_lsb: float, col_block_offset) -> jax.Array:
    """(2,) int32 SMEM payload: [noise seed, global column-block offset]."""
    if seed is None:
        if noise_lsb > 0.0:
            raise ValueError("noise_lsb > 0 requires a seed")
        seed = jnp.zeros((), jnp.int32)
    seed = jnp.asarray(seed, jnp.int32).reshape(())
    off = (jnp.zeros((), jnp.int32) if col_block_offset is None
           else jnp.asarray(col_block_offset, jnp.int32).reshape(()))
    return jnp.stack([seed, off])


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "bm", "bn", "bk", "interpret", "num_col_blocks"),
)
def abfp_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    cfg: QuantConfig,
    seed: Optional[jax.Array] = None,
    *,
    bm: Optional[int] = None,
    bn: int = DEFAULT_BN,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
    col_block_offset: Optional[jax.Array] = None,
    num_col_blocks: Optional[int] = None,
) -> jax.Array:
    """y = ABFP(x @ w); x: (..., K), w: (K, N) -> (..., N) in cfg.out_dtype.

    ``seed``: int32 scalar seeding the in-kernel noise hash (required when
    cfg.noise_lsb > 0).  ``interpret`` defaults to True off-TPU so the same
    call validates on CPU and runs compiled on TPU.  ``bm`` defaults to the
    decode-aware ``auto_bm`` (8-row blocks for 1–8 row decode matmuls).

    ``col_block_offset`` (runtime int32) and ``num_col_blocks`` (static):
    tensor-parallel salt globalization — a column shard owning blocks
    [off, off + N_local/bn) of a global grid with ``num_col_blocks`` column
    blocks draws the same noise the single-device grid draws for those
    blocks (see ``_abfp_contrib``).  Leave unset for single-device calls.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = cfg.tile_width
    if bk is None:
        bk = default_bk(n, x.shape[-1])
    assert bk % n == 0, (bk, n)

    batch_shape = x.shape[:-1]
    k_dim, n_dim = w.shape
    x2 = x.reshape(-1, k_dim).astype(jnp.float32)
    m_dim = x2.shape[0]
    if bm is None:
        bm = auto_bm(m_dim)

    mp, kp, np_ = _ceil_to(m_dim, bm), _ceil_to(k_dim, bk), _ceil_to(n_dim, bn)
    x2 = jnp.pad(x2, ((0, mp - m_dim), (0, kp - k_dim)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k_dim), (0, np_ - n_dim)))

    seed = _seed_smem(seed, cfg.noise_lsb, col_block_offset)

    grid = (mp // bm, np_ // bn, kp // bk)
    tk = bk // n

    kernel = functools.partial(_abfp_matmul_kernel, cfg=cfg, tk=tk, n=n,
                               nj=num_col_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # seed
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),        # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),        # w
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), cfg.out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(seed, x2, wp)

    return out[:m_dim, :n_dim].reshape(*batch_shape, n_dim)


# ---------------------------------------------------------------------------
# Packed-weight kernel: pre-quantized int8 codes + bf16 scales from HBM
# ---------------------------------------------------------------------------


def _abfp_matmul_packed_kernel(
    seed_ref,  # SMEM (2,) int32: [seed, col-block offset]
    x_ref,     # VMEM (bm, bk) f32
    wc_ref,    # VMEM (bk, bn) int8 weight codes
    sw_ref,    # VMEM (tk, bn) scale_dtype weight scales
    *refs,     # [g_ref (tk, 1) f32 gains]  o_ref (bm, bn)  acc_ref scratch
    cfg: QuantConfig,
    tk: int,
    n: int,
    nj: Optional[int] = None,
    has_gains: bool = False,
):
    """Packed-weight kernel body: codes/scales (and optional per-tile
    gains) stream straight from HBM into the shared ABFP core."""
    if has_gains:
        g_ref, o_ref, acc_ref = refs
        g = g_ref[...].astype(jnp.float32).reshape(tk)
    else:
        o_ref, acc_ref = refs
        g = None

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    bn = wc_ref.shape[1]

    xt = x_ref[...].astype(jnp.float32).reshape(bm, tk, n)

    # Weight side: NO max/round/clip — codes and scales come straight from
    # HBM.  int8 -> bf16/f32 cast is exact for |code| <= 127.
    from repro.core.abfp import code_dtype
    cdt = code_dtype(max(cfg.bits_x, cfg.bits_w))
    wq = wc_ref[...].astype(cdt).reshape(tk, n, bn)  # (tk, n, bn)
    sw = sw_ref[...].astype(jnp.float32)             # (tk, bn)

    acc_ref[...] += _abfp_contrib(xt, wq, sw, seed_ref, cfg, tk, n, nj=nj,
                                  g=g)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "bm", "bn", "bk", "interpret", "num_col_blocks"),
)
def abfp_matmul_packed_pallas(
    x: jax.Array,
    pw: PackedWeight,
    cfg: QuantConfig,
    seed: Optional[jax.Array] = None,
    *,
    bm: Optional[int] = None,
    bn: int = DEFAULT_BN,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
    col_block_offset: Optional[jax.Array] = None,
    num_col_blocks: Optional[int] = None,
) -> jax.Array:
    """y = ABFP(x @ w) from a pre-packed weight; x: (..., K) -> (..., N).

    ``pw`` must be a 2-D ``PackedWeight`` (no leading batch axes) packed at
    this ``cfg``'s tile width / bits_w.  Bit-identical to
    ``abfp_matmul_pallas(x, w, cfg, seed)`` at matching block sizes,
    without re-deriving weight scales/codes on every grid step.

    When ``pw.gains`` is present (the ``mode="abfp_fused"`` adaptive-gain
    packing), each K tile's partial product is amplified by its own G_t
    before the ADC and divided out after (see ``_abfp_contrib``); with
    all-ones gains the output is bit-identical to a gain-free pack at
    ``cfg.gain == 1.0``.

    ``col_block_offset`` / ``num_col_blocks``: tensor-parallel noise-salt
    globalization, as in ``abfp_matmul_pallas``.
    """
    if pw.codes.ndim != 2:
        raise ValueError(
            f"packed kernel takes a 2-D PackedWeight, got codes "
            f"{pw.codes.shape}; index leading axes first")
    if pw.tile_width != cfg.tile_width or pw.bits_w != cfg.bits_w:
        raise ValueError(
            f"PackedWeight(n={pw.tile_width}, bits_w={pw.bits_w}) does not "
            f"match cfg(n={cfg.tile_width}, bits_w={cfg.bits_w})")
    if pw.scales.dtype != jnp.dtype(cfg.scale_dtype):
        raise ValueError(
            f"PackedWeight scales are {pw.scales.dtype} but cfg.scale_dtype "
            f"is {jnp.dtype(cfg.scale_dtype)}; re-pack at this config")
    if cfg.noise_lsb > 0.0 and bn % 128 != 0:
        # The noise salt depends on the column-block count; only bn multiples
        # of the 128-lane pre-padding guarantee the packed and unpacked grids
        # (and thus their noise streams) coincide.
        raise ValueError(
            f"noise_lsb > 0 requires bn to be a multiple of 128 for the "
            f"packed kernel (got bn={bn}): other block widths change the "
            f"grid vs the unpacked kernel and break noise bit-identity")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = cfg.tile_width
    k_dim, n_dim = pw.k, pw.n_out
    if x.shape[-1] != k_dim:
        raise ValueError(f"x K dim {x.shape[-1]} != packed weight K {k_dim}")
    if bk is None:
        bk = default_bk(n, k_dim)
    assert bk % n == 0, (bk, n)

    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, k_dim).astype(jnp.float32)
    m_dim = x2.shape[0]
    if bm is None:
        bm = auto_bm(m_dim)

    # Pad x's K to the packed Kp (zero activations against real tiles are
    # exact no-ops), then everything to block multiples.  The weight is
    # already lane-aligned from pack time, so for the default bn (and any
    # bn that is a multiple of 128) the pads below are no-ops and the hot
    # path streams pw.codes/pw.scales exactly as stored.
    kp0, npad0 = pw.kp, pw.n_padded
    mp, kp, np_ = _ceil_to(m_dim, bm), _ceil_to(kp0, bk), _ceil_to(npad0, bn)
    x2 = jnp.pad(x2, ((0, mp - m_dim), (0, kp - k_dim)))
    wc, sw = pw.codes, pw.scales
    if kp > kp0 or np_ > npad0:
        wc = jnp.pad(wc, ((0, kp - kp0), (0, np_ - npad0)))
        sw = jnp.pad(sw, ((0, (kp - kp0) // n), (0, np_ - npad0)))

    seed = _seed_smem(seed, cfg.noise_lsb, col_block_offset)

    grid = (mp // bm, np_ // bn, kp // bk)
    tk = bk // n

    has_gains = pw.gains is not None
    kernel = functools.partial(
        _abfp_matmul_packed_kernel, cfg=cfg, tk=tk, n=n, nj=num_col_blocks,
        has_gains=has_gains)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),                 # seed
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),        # x
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),        # codes
        pl.BlockSpec((tk, bn), lambda i, j, k: (k, j)),        # scales
    ]
    inputs = [seed, x2, wc, sw]
    if has_gains:
        # Per-tile gains ride along as a (T, 1) column, blocked over K like
        # the scales (pad tiles amplify zero scales: exact no-ops).
        gp = jnp.pad(pw.gains.astype(jnp.float32),
                     (0, kp // n - pw.num_tiles),
                     constant_values=1.0).reshape(-1, 1)
        in_specs.append(pl.BlockSpec((tk, 1), lambda i, j, k: (k, 0)))
        inputs.append(gp)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), cfg.out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*inputs)

    return out[:m_dim, :n_dim].reshape(*batch_shape, n_dim)
