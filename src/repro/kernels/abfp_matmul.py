"""Fused ABFP tiled-matmul Pallas TPU kernel.

The compute hot-spot of ABFP simulation.  A naive XLA implementation either
materializes the (T, M, N) per-tile partial-product tensor in HBM (T = K/n —
a 64x blow-up at K=8192, n=128; 512x at n=8) or re-reads the operands T
times.  This kernel keeps everything tile-local in VMEM:

  grid = (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics).
  Each step loads x_blk (bm, bk) and w_blk (bk, bn), splits the K block into
  tk = bk/n ABFP tiles, and per tile:

    s_x = max|x_tile|  (bf16-rounded)          s_w = max|w_tile|
    x_q = Q(x/s_x; d_X, 1)                     w_q = Q(w/s_w; d_W, 1)
    p   = x_q . w_q                 (MXU batched dot over the tk tiles)
    y_q = Q(G*p + E; n*d_Y, n)      (ADC with gain and uniform noise)
    acc += y_q * s_x * s_w / G      (FLOAT32 accumulator in VMEM scratch)

  The accumulator is written to HBM once, as BFLOAT16, on the last K step.

AMS noise uses a counter-based murmur3-style hash PRNG (seed, program ids,
tile index) -> uniform, identical under `interpret=True` on CPU and compiled
TPU execution, so the oracle comparison and noise statistics are testable in
this container.

TPU adaptation note (DESIGN.md §2): the paper's analog device processes one
n-wide tile per clock; here tk tiles are batched into one MXU dot_general so
small n (8/32) still feeds the 128x128 systolic array efficiently — the tile
*semantics* (per-tile ADC quantization) are preserved exactly.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.abfp import QuantConfig

DEFAULT_BM = 128
DEFAULT_BN = 128


def default_bk(n: int, k: int) -> int:
    """K-block: multiple of the ABFP tile width, capped to bound VMEM.

    tk = bk/n partial products of (bm, bn) f32 live in VMEM: at bm=bn=128,
    bk=512 -> tk*64KiB <= 4 MiB (n=8 uses bk=256 -> 2 MiB).
    """
    cap = 256 if n <= 8 else 512
    bk = min(cap, max(n, k))
    return max(n, (bk // n) * n)


# ---------------------------------------------------------------------------
# Counter-based uniform PRNG (murmur3 finalizer lattice hash)
# ---------------------------------------------------------------------------


def _hash_uniform(shape, seed, salt):
    """Deterministic uniform [0, 1) lattice: hash(row, col, seed, salt)."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    x = (
        r * jnp.uint32(0x9E3779B9)
        + c * jnp.uint32(0x85EBCA6B)
        + seed.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35)
        + salt.astype(jnp.uint32) * jnp.uint32(0x27D4EB2F)
    )
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) / jnp.float32(1 << 24)


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------


def _abfp_matmul_kernel(
    seed_ref,  # SMEM (1,) int32
    x_ref,     # VMEM (bm, bk)
    w_ref,     # VMEM (bk, bn)
    o_ref,     # VMEM (bm, bn)
    acc_ref,   # VMEM scratch (bm, bn) f32
    *,
    cfg: QuantConfig,
    tk: int,
    n: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = x_ref.shape
    bn = w_ref.shape[1]

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)

    xt = x.reshape(bm, tk, n)                       # (bm, tk, n)
    wt = w.reshape(tk, n, bn)                       # (tk, n, bn)

    # Adaptive per-tile scales, stored in bf16 (paper Sec. III).
    sx = jnp.max(jnp.abs(xt), axis=2)               # (bm, tk)
    sw = jnp.max(jnp.abs(wt), axis=1)               # (tk, bn)
    sx = sx.astype(cfg.scale_dtype).astype(jnp.float32)
    sw = sw.astype(cfg.scale_dtype).astype(jnp.float32)
    sx_safe = jnp.where(sx == 0.0, 1.0, sx)
    sw_safe = jnp.where(sw == 0.0, 1.0, sw)

    # Eq. 2: normalize and encode operands as integer codes (DAC).
    lx = jnp.float32(2 ** (cfg.bits_x - 1) - 1)
    lw = jnp.float32(2 ** (cfg.bits_w - 1) - 1)
    xq = jnp.clip(jnp.round(xt / sx_safe[:, :, None] * lx), -lx, lx)
    wq = jnp.clip(jnp.round(wt / sw_safe[:, None, :] * lw), -lw, lw)
    # bf16 codes are exact for <= 9-bit operands and feed the MXU at its
    # bf16 rate (vs ~1/8 rate for f32) — see core.abfp.code_dtype.
    from repro.core.abfp import code_dtype
    cdt = code_dtype(max(cfg.bits_x, cfg.bits_w))
    xq = xq.astype(cdt)
    wq = wq.astype(cdt)

    # Batched MXU dot over the tk tiles: (tk, bm, n) @ (tk, n, bn).
    # Integer-valued operands: the f32-accumulated dot is EXACT
    # (|p| <= n*L_x*L_w < 2^24 at 8 bits), matching the analog MAC array and
    # the jnp oracle bit-for-bit.
    p = jax.lax.dot_general(
        xq.transpose(1, 0, 2),
        wq,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                               # (tk, bm, bn)

    # Eq. 5/7: the ADC in code units — same fused f32 constant as the oracle
    # so round-half-even ties resolve identically.
    v = p * jnp.float32(cfg.adc_code_scale)
    if cfg.noise_lsb > 0.0:
        # One independent uniform noise draw per partial output, in LSB units.
        salt = (i * pl.num_programs(1) + j) * nk + k
        u = _hash_uniform(
            (tk * bm, bn),
            seed_ref[0],
            jnp.uint32(salt),
        ).reshape(tk, bm, bn)
        v = v + (u - 0.5) * jnp.float32(2.0 * cfg.noise_lsb)
    ly = jnp.float32(2 ** (cfg.bits_y - 1) - 1)
    yq = jnp.clip(jnp.round(v), -ly, ly) * jnp.float32(cfg.bin_y)

    # Eq. 6: rescale partials and accumulate in FLOAT32.
    contrib = jnp.sum(
        yq * sx.T[:, :, None] * sw[:, None, :], axis=0
    ) / jnp.float32(cfg.gain)                        # (bm, bn)
    acc_ref[...] += contrib

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Host-side wrapper
# ---------------------------------------------------------------------------


def _ceil_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("cfg", "bm", "bn", "bk", "interpret")
)
def abfp_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    cfg: QuantConfig,
    seed: Optional[jax.Array] = None,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """y = ABFP(x @ w); x: (..., K), w: (K, N) -> (..., N) in cfg.out_dtype.

    ``seed``: int32 scalar seeding the in-kernel noise hash (required when
    cfg.noise_lsb > 0).  ``interpret`` defaults to True off-TPU so the same
    call validates on CPU and runs compiled on TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = cfg.tile_width
    if bk is None:
        bk = default_bk(n, x.shape[-1])
    assert bk % n == 0, (bk, n)

    batch_shape = x.shape[:-1]
    k_dim, n_dim = w.shape
    x2 = x.reshape(-1, k_dim).astype(jnp.float32)
    m_dim = x2.shape[0]

    mp, kp, np_ = _ceil_to(m_dim, bm), _ceil_to(k_dim, bk), _ceil_to(n_dim, bn)
    x2 = jnp.pad(x2, ((0, mp - m_dim), (0, kp - k_dim)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k_dim), (0, np_ - n_dim)))

    if seed is None:
        if cfg.noise_lsb > 0.0:
            raise ValueError("noise_lsb > 0 requires a seed")
        seed = jnp.zeros((1,), jnp.int32)
    else:
        seed = jnp.asarray(seed, jnp.int32).reshape((1,))

    grid = (mp // bm, np_ // bn, kp // bk)
    tk = bk // n

    kernel = functools.partial(_abfp_matmul_kernel, cfg=cfg, tk=tk, n=n)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # seed
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),        # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),        # w
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), cfg.out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(seed, x2, wp)

    return out[:m_dim, :n_dim].reshape(*batch_shape, n_dim)
