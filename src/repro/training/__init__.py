"""repro.training — train/serve step factories, QAT/DNF recipes."""
from repro.training.train_lib import (  # noqa: F401
    TrainConfig, TrainState, cross_entropy, make_serve_steps, make_train_step)
