"""Finetuning recipes — paper Sec. IV: QAT and Differential Noise Finetuning.

QAT: the forward pass runs the full ABFP simulation (tiling, scaling,
quantization, gain, ADC noise) with STE gradients (Eq. 8) — just the normal
train step with ``TrainConfig.quant.mode = "abfp_ref"``.

DNF (the paper's novel method, Fig. 3):
  1. ``capture_histograms`` — ONE batch through the paired FLOAT/ABFP
     forward (``models.forward_capture``); per-layer dy histograms (100 bins,
     +0.5 smoothing) fitted once.
  2. ``make_dnf_train_step`` — FLOAT forward + per-layer additive noise
     sampled from the histograms (Eq. 9); backward is plain FLOAT32.
     No tiling/quantization in the loop => the 4x speedup the paper reports.
  3. ``select_layers_by_std`` (core.dnf) can restrict injection to the most
     susceptible layers (the paper's SSD-ResNet34 tailoring).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.abfp import QuantConfig
from repro.core.dnf import NoiseHistogram
from repro.models import forward, forward_capture
from repro.models.layers import Numerics
from repro.training.train_lib import TrainState, chunked_cross_entropy


def capture_histograms(
    params,
    tokens,
    mcfg: ModelConfig,
    quant: QuantConfig,
    *,
    key,
    num_bins: int = 100,
    encoder_features=None,
) -> tuple[NoiseHistogram, list]:
    """Fit per-layer differential-noise histograms from one batch.

    Returns (stacked_histograms, per_layer_std list — the Fig. 5 analysis).
    """
    nx_float = Numerics(QuantConfig(mode="float"))

    counter = [0]

    def abfp_factory():
        counter[0] += 1
        return Numerics(quant, jax.random.fold_in(key, counter[0]))

    _, deltas = forward_capture(params, tokens, mcfg, nx_float, abfp_factory,
                                encoder_features=encoder_features)
    hists = [NoiseHistogram.fit(np.asarray(d), num_bins=num_bins)
             for d in deltas]
    stds = [float(h.std) for h in hists]
    return NoiseHistogram.stack(hists), stds


def make_dnf_train_step(mcfg: ModelConfig, optimizer,
                        hists: NoiseHistogram,
                        layer_mask: Optional[list] = None):
    """DNF train step: FLOAT forward + histogram noise at layer outputs.

    ``layer_mask``: optional per-layer bools — True layers get noise (the
    high-σ tailoring).  Implemented by zeroing masked layers' histograms.
    """
    if layer_mask is not None:
        mask = jnp.asarray(layer_mask, jnp.float32)
        # Zero out masked layers' sampled values by collapsing their edges.
        hists = NoiseHistogram(
            edges=hists.edges * mask[:, None],
            cum=hists.cum,
            mean=hists.mean * mask,
            std=hists.std * mask,
        )

    def loss_fn(params, batch, key):
        nx = Numerics(QuantConfig(mode="float"))
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        hidden, aux = forward(params, inputs, mcfg, nx,
                              dnf=hists, dnf_key=key, return_hidden=True)
        loss = chunked_cross_entropy(params, hidden, labels, mcfg, nx)
        return loss, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def init_state(params) -> TrainState:
        return TrainState(params, optimizer.init(params), None,
                          jnp.zeros((), jnp.int32))

    def train_step(state: TrainState, batch: dict, key):
        (_, (loss, _)), grads = grad_fn(state.params, batch, key)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        return (TrainState(params, opt_state, None, state.step + 1),
                {"loss": loss})

    return init_state, train_step


def evaluate_abfp(params, batches, mcfg: ModelConfig, quant: QuantConfig,
                  *, key) -> float:
    """Mean ABFP next-token accuracy over batches (the quality metric used by
    our Table II/III analog benchmarks)."""
    correct = total = 0
    for i, batch in enumerate(batches):
        nx = Numerics(quant, jax.random.fold_in(key, i))
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        logits, _ = forward(params, inputs, mcfg, nx)
        pred = jnp.argmax(logits, axis=-1)
        correct += int((pred == labels).sum())
        total += labels.size
    return correct / max(total, 1)
