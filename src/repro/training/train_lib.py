"""Train/serve step factories.

``make_train_step`` builds a jit-able (state, batch, key) -> (state, metrics)
with:
  * next-token cross-entropy in f32 (+ MoE aux loss),
  * microbatched gradient accumulation (``lax.scan`` over microbatches — this
    is what bounds activation memory at train_4k on 16 GB chips, together
    with per-layer remat),
  * optional gradient compression at the DP boundary (bf16 / int8+EF),
  * any ``repro.optim`` optimizer (mixed precision, ZeRO-1-shardable states).

``make_serve_steps`` builds prefill and decode callables for the serving
engine and the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abfp import QuantConfig
from repro.distributed import collectives
from repro.models import decode_step, forward, init_decode_state
from repro.models.layers import Numerics
from repro.models.lm import lm_head_logits

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    ef: Optional[collectives.ErrorFeedbackState]
    step: Array


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean next-token NLL in f32."""
    ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def chunked_cross_entropy(params, hidden: Array, labels: Array, mcfg, nx,
                          chunk: int = 256) -> Array:
    """CE without materializing (B, S, V) logits: scan over sequence chunks
    through the LM head.  At V=256k this is the difference between a ~GB-sized
    chunk buffer and a TB-sized full-logits tensor."""
    b, s, d = hidden.shape
    if s % chunk != 0:
        chunk = s                                        # smoke-scale fallback
    nc = s // chunk
    hc = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(acc, xs):
        h, lab = xs
        logits = lm_head_logits(params, h, mcfg, nx)
        ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(ll, lab[..., None], axis=-1)[..., 0]
        return acc + nll.sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc))
    return total / (b * s)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    aux_loss_weight: float = 0.01
    compression: Optional[str] = None       # None | "bf16" | "int8"
    quant: QuantConfig = QuantConfig(mode="float")


def make_train_step(mcfg: ModelConfig, optimizer, tcfg: TrainConfig,
                    mesh=None):
    """Returns (init_state_fn, train_step_fn)."""

    def loss_fn(params, batch, key):
        nx = Numerics(tcfg.quant, key)
        if "labels" in batch:           # stub-frontend (vlm): embeds + labels
            inputs, labels = batch["embeds"], batch["labels"]
        else:                            # LM: next-token on a (B, S+1) batch
            tokens = batch["tokens"]
            inputs, labels = tokens[:, :-1], tokens[:, 1:]
        hidden, aux = forward(params, inputs, mcfg, nx,
                              encoder_features=batch.get("encoder_features"),
                              mesh=mesh, return_hidden=True)
        loss = chunked_cross_entropy(params, hidden, labels, mcfg, nx)
        return loss + tcfg.aux_loss_weight * aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def init_state(params) -> TrainState:
        ef = (collectives.init_error_feedback(params)
              if tcfg.compression == "int8" else None)
        return TrainState(params, optimizer.init(params), ef,
                          jnp.zeros((), jnp.int32))

    def train_step(state: TrainState, batch: dict, key: Array):
        nm = tcfg.microbatches
        if nm > 1:
            b = jax.tree.leaves(batch)[0].shape[0]
            assert b % nm == 0, (b, nm)
            mb = jax.tree.map(
                lambda a: a.reshape(nm, b // nm, *a.shape[1:]), batch)

            def acc_body(carry, xs):
                g_acc, l_acc, a_acc = carry
                bslice, i = xs
                (_, (loss, aux)), grads = grad_fn(
                    state.params, bslice, jax.random.fold_in(key, i))
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss, a_acc + aux), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0), jnp.float32(0)),
                (mb, jnp.arange(nm)))
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss, aux = loss / nm, aux / nm
        else:
            (_, (loss, aux)), grads = grad_fn(state.params, batch, key)

        grads, ef = collectives.apply_compression(
            grads, tcfg.compression, state.ef)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        metrics = {"loss": loss, "aux_loss": aux,
                   "grad_norm": _global_norm(grads)}
        return TrainState(params, opt_state, ef, state.step + 1), metrics

    return init_state, train_step


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def make_serve_steps(mcfg: ModelConfig,
                     quant: QuantConfig = QuantConfig(mode="float")):
    """Returns (prefill_fn, decode_fn, init_state_fn).

    prefill_fn(params, tokens (B, S))            -> logits (B, S, V)
    decode_fn(params, state, token (B,))         -> (logits (B, V), state)
    init_state_fn(batch, max_len)                -> decode state
    """

    def prefill(params, tokens, key=None, encoder_features=None):
        nx = Numerics(quant, key)
        logits, _ = forward(params, tokens, mcfg, nx,
                            encoder_features=encoder_features)
        return logits

    def decode(params, state, token, key=None, enc_kv=None):
        nx = Numerics(quant, key)
        return decode_step(params, state, token, mcfg, nx, enc_kv=enc_kv)

    def init_state(batch, max_len):
        return init_decode_state(mcfg, batch, max_len)

    return prefill, decode, init_state
