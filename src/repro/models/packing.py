"""Whole-model ABFP weight packing: quantize once, serve forever.

``pack_model_params`` walks a model param tree and replaces every dense
(weight-activation matmul) weight with a ``repro.core.abfp.PackedWeight``
— int8 tile codes + bf16 per-(tile, column) scales — so the serving engine
never re-derives weight scales/codes on the hot path.  This is the digital
analogue of the paper's AMS deployment: weight tiles are programmed into
the analog array once, then only activations stream through.

What gets packed (by leaf name, matching the init_* constructors):

  * attention projections        wq wk wv wo        (also xLSTM mLSTM's)
  * MLP / MoE expert weights     wi wg wo
  * recurrent block projections  w_gate w_in w_rg w_ig w_out
                                 w_up w_down w_if w_x
  * the LM head                  lm_head (inserted for tied embeddings:
                                 ``embed.T`` is packed under "lm_head" and
                                 ``_lm_head`` picks it up preferentially)

Leading batch axes (scan-stacked groups (NG, K, N); MoE experts
(..., E, K, N)) are preserved — ``pack_abfp_weight`` packs the trailing
(K, N) axes and ``PackedWeight`` slices/indexes like any pytree, so
``jax.lax.scan`` over groups and ``params["wi"][ex]`` work unchanged.

Embedding tables (gather, not matmul), norm scales/biases, and router
weights (tiny, range-sensitive — paper Sec. V keeps them digital) stay in
their original dtype.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.core.abfp import PackedWeight, QuantConfig, pack_abfp_weight

# Leaf names that feed Numerics.dense as the weight operand.
DENSE_WEIGHT_NAMES = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wg",
    "w_gate", "w_in", "w_rg", "w_ig", "w_out",
    "w_up", "w_down", "w_if", "w_x",
    "lm_head",
})


def _leaf_name(path) -> str:
    """Last dict key / attr name on a tree path."""
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", getattr(last, "idx", last))))


def pack_model_params(params: dict, cfg: QuantConfig,
                      mcfg: Any = None, mesh: Any = None) -> dict:
    """Return a copy of ``params`` with all dense weights pre-packed.

    ``cfg`` supplies the tile width / bit widths the weights are packed
    for; serving must then run with a config whose tile_width and bits_w
    match (the packed kernel validates this).  ``mcfg`` (optional
    ModelConfig) enables the tied-embeddings LM-head insertion.

    ``mesh`` (optional ``jax.sharding.Mesh``): the sharded serving path —
    the packed tree is placed per ``distributed.sharding
    .serving_param_spec_tree``: each ``PackedWeight``'s int8 codes and bf16
    scales are column-sharded TOGETHER over the 'model' axis (per-(tile,
    col) scales travel with their codes), unsplittable weights and digital
    leaves (norms, embed, routers) replicate.

    ``mode="abfp_fused"`` additionally derives per-tile ADC gains from the
    packed codes (``core.abfp.adaptive_tile_gains`` — the paper's
    amplification knob) and stores them as ``PackedWeight.gains``; the
    packed and fused kernels amplify each tile before the output quantizer
    and divide the gain back out.  At ``cfg.gain == 1.0`` the gains are all
    ones and the packed tree is numerically identical to an
    ``abfp_packed`` pack.
    """
    adaptive = cfg.mode == "abfp_fused"

    def pack(path, leaf):
        if isinstance(leaf, PackedWeight):
            return leaf
        if _leaf_name(path) in DENSE_WEIGHT_NAMES and getattr(
                leaf, "ndim", 0) >= 2:
            return pack_abfp_weight(leaf, cfg, adaptive_gain=adaptive)
        return leaf

    packed = jax.tree_util.tree_map_with_path(pack, params)

    tied = bool(getattr(mcfg, "tie_embeddings", False)) \
        and "lm_head" not in params
    if tied:
        # The tied head multiplies by embed.T; pack that transpose once so
        # decode never touches the float embedding table for the head.
        packed["lm_head"] = pack_abfp_weight(params["embed"].T, cfg,
                                             adaptive_gain=adaptive)
    if mesh is not None:
        from repro.distributed.sharding import shard_serving_params
        packed = shard_serving_params(packed, mesh, cfg)
    return packed


def packed_param_bytes(params) -> int:
    """Total HBM bytes of a (possibly partially) packed param tree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(leaf, PackedWeight):
            total += leaf.nbytes()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
