"""Mixture-of-Experts FFN.

Three execution paths share one routing front-end (softmax -> top-k ->
renormalized gates + switch-style load-balancing aux loss):

  * ``_ragged_moe``   — single-shard sort + ``jax.lax.ragged_dot``: tokens are
    replicated k times, sorted by expert, run through grouped matmuls, and
    scatter-added back.  No (T, E, C) one-hot dispatch tensor is ever built —
    the classic GShard dispatch einsum is infeasible at 384 experts.
  * ``moe_block_sharded`` — expert parallelism via ``shard_map``: experts are
    sharded over the 'model' mesh axis; every shard routes all of its local
    tokens, keeps the (token, expert) pairs that map to its local experts
    (fixed capacity with dropping, GShard-style), computes them with
    ragged_dot, and a single psum over 'model' combines the partial outputs —
    the same collective cost as a tensor-parallel FFN all-reduce, with no
    all-to-all required because activations are already replicated over
    'model'.
  * ``_loop_moe``     — ABFP/QAT path: a static loop over experts so every
    expert matmul goes through the quantized ``Numerics.dense`` (ragged_dot
    cannot carry per-tile ABFP semantics).  Used for quantization-aware work
    at smoke scale; guarded against huge expert counts.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import Numerics

Array = jax.Array


def init_moe(key, mcfg, layer_shape=()) -> dict:
    e, d, f = mcfg.num_experts, mcfg.d_model, mcfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    shape = lambda *s: layer_shape + s  # noqa: E731
    return {
        "router": (jax.random.normal(k1, shape(d, e)) * d**-0.5).astype(jnp.float32),
        "wi": (jax.random.normal(k2, shape(e, d, f))
               * d**-0.5).astype(mcfg.param_dtype),
        "wg": (jax.random.normal(k3, shape(e, d, f))
               * d**-0.5).astype(mcfg.param_dtype),
        "wo": (jax.random.normal(k4, shape(e, f, d))
               * f**-0.5).astype(mcfg.param_dtype),
    }


def _route(xf: Array, router_w: Array, mcfg):
    """Returns (gates (T,k), expert_ids (T,k), aux_loss scalar)."""
    logits = jnp.matmul(xf.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gates, eids = jax.lax.top_k(probs, mcfg.experts_per_token)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-transformer load-balance loss: E * sum_e f_e * p_e.
    e = mcfg.num_experts
    density = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(1.0)
    density = density / jnp.maximum(density.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(density * p_mean)
    return gates, eids, aux


def _expert_ffn_ragged(xs, wi, wg, wo, group_sizes, mcfg):
    """Grouped SwiGLU FFN over expert-sorted rows."""
    f32 = jnp.float32
    hi = jax.lax.ragged_dot(xs, wi.astype(xs.dtype), group_sizes,
                            preferred_element_type=f32)
    hg = jax.lax.ragged_dot(xs, wg.astype(xs.dtype), group_sizes,
                            preferred_element_type=f32)
    if mcfg.mlp_type == "geglu":
        h = jax.nn.gelu(hg) * hi
    else:
        h = jax.nn.silu(hg) * hi
    out = jax.lax.ragged_dot(h.astype(xs.dtype), wo.astype(xs.dtype),
                             group_sizes, preferred_element_type=f32)
    return out


def _ragged_moe(xf, params, gates, eids, mcfg):
    t, d = xf.shape
    k = mcfg.experts_per_token
    e = mcfg.num_experts
    flat_e = eids.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e)                               # stable
    token_idx = order // k
    xs = jnp.take(xf, token_idx, axis=0)                      # (T*k, d)
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)
    out = _expert_ffn_ragged(xs, params["wi"], params["wg"], params["wo"],
                             group_sizes, mcfg)
    w = jnp.take(gates.reshape(-1), order)                    # (T*k,)
    y = jnp.zeros((t, d), jnp.float32).at[token_idx].add(out * w[:, None])
    return y.astype(xf.dtype)


def _loop_moe(xf, params, gates, eids, mcfg, nx: Numerics):
    """ABFP path: every expert matmul through the quantized dense.  Computes
    all tokens through each expert and masks — O(E/k) overcompute, acceptable
    at QAT/smoke scale, exact ABFP semantics per expert tile."""
    if mcfg.num_experts > 64:
        raise ValueError(
            "ABFP-mode MoE uses the per-expert loop; >64 experts is "
            "intentionally unsupported (see module docstring)")
    t, d = xf.shape
    y = jnp.zeros((t, d), jnp.float32)
    for ex in range(mcfg.num_experts):
        sel = (eids == ex).astype(jnp.float32)                # (T, k)
        gate_e = jnp.sum(gates * sel, axis=-1)                # (T,)
        hi = nx.dense(xf, params["wi"][ex])
        hg = nx.dense(xf, params["wg"][ex])
        act = jax.nn.gelu if mcfg.mlp_type == "geglu" else jax.nn.silu
        h = (act(hg.astype(jnp.float32)) * hi.astype(jnp.float32)).astype(xf.dtype)
        out = nx.dense(h, params["wo"][ex]).astype(jnp.float32)
        y = y + out * gate_e[:, None]
    return y.astype(xf.dtype)


def moe_block(params: dict, x: Array, mcfg, nx: Numerics):
    """Single-shard MoE.  x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    gates, eids, aux = _route(xf, params["router"], mcfg)
    if nx.quant.mode == "float":
        y = _ragged_moe(xf, params, gates, eids, mcfg)
    else:
        y = _loop_moe(xf, params, gates, eids, mcfg, nx)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map over the 'model' axis)
# ---------------------------------------------------------------------------


def moe_block_sharded(params: dict, x: Array, mcfg, nx: Numerics, mesh,
                      *, batch_axes=("pod", "data"), expert_axis="model"):
    """Expert-parallel MoE: experts sharded over ``expert_axis``.

    Activations enter sharded over ``batch_axes`` (replicated over the expert
    axis), so no all-to-all is needed: each shard computes its local experts
    for its local tokens at fixed capacity and one psum combines.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    n_shards = mesh.shape[expert_axis]
    e_local = mcfg.num_experts // n_shards
    assert e_local * n_shards == mcfg.num_experts

    b, s, d = x.shape
    batch_spec = P(batch_axes, None, None)

    def local_fn(xl, router_w, wi, wg, wo):
        # xl: (B_loc, S, d) — replicated over expert_axis.
        bl = xl.shape[0]
        xf = xl.reshape(bl * s, d)
        t = xf.shape[0]
        k = mcfg.experts_per_token
        gates, eids, aux = _route(xf, router_w, mcfg)

        shard = jax.lax.axis_index(expert_axis)
        lo = shard * e_local
        local_id = eids - lo                                  # (T, k)
        mine = (local_id >= 0) & (local_id < e_local)

        flat_local = jnp.where(mine, local_id, e_local).reshape(-1)
        flat_gates = jnp.where(mine, gates, 0.0).reshape(-1)
        order = jnp.argsort(flat_local)                       # mine first
        capacity = int(
            (t * k / n_shards) * mcfg.capacity_factor) + 1
        capacity = min(capacity, t * k)
        rows = order[:capacity]
        token_idx = rows // k
        xs = jnp.take(xf, token_idx, axis=0)                  # (C, d)
        sorted_ids = flat_local[rows]
        counts = jnp.bincount(sorted_ids, length=e_local + 1).astype(jnp.int32)
        # Overflow/not-mine rows fold into the last real group with zero gate.
        group_sizes = counts[:e_local].at[e_local - 1].add(counts[e_local])
        w_rows = jnp.where(sorted_ids < e_local, flat_gates[rows], 0.0)

        out = _expert_ffn_ragged(xs, wi, wg, wo, group_sizes, mcfg)
        y = jnp.zeros((t, d), jnp.float32).at[token_idx].add(
            out * w_rows[:, None])
        y = jax.lax.psum(y, expert_axis)
        # aux is identical across expert shards (same local tokens) but
        # differs across data shards: mean over everything so the returned
        # scalar equals the global-batch load-balance loss.
        aux = jax.lax.pmean(aux, (expert_axis,) + tuple(batch_axes))
        return y.reshape(bl, s, d).astype(xl.dtype), aux

    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            batch_spec,
            P(),                                   # router replicated
            P(expert_axis, None, None),
            P(expert_axis, None, None),
            P(expert_axis, None, None),
        ),
        out_specs=(batch_spec, P()),
        check_rep=False,
    )(x, params["router"], params["wi"], params["wg"], params["wo"])
    return y, aux
