"""Modality frontend STUBS (per assignment: ``input_specs()`` provides
precomputed frame/patch embeddings; the transformer backbone is the model).

These helpers only generate correctly-shaped stand-ins:
  * audio (whisper): (B, frames, d_model) frame embeddings — the conv
    subsampler output the real frontend would produce.
  * vision (phi-3-vision): (B, seq, d_model) combined patch+token embedding
    sequence — the CLIP projector output spliced into the text stream.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_stub_features(key, batch: int, frames: int, d_model: int,
                        dtype=jnp.bfloat16):
    return (jax.random.normal(key, (batch, frames, d_model)) * 0.02).astype(dtype)


def vision_stub_embeddings(key, batch: int, seq: int, d_model: int,
                           dtype=jnp.bfloat16):
    return (jax.random.normal(key, (batch, seq, d_model)) * 0.02).astype(dtype)
