"""Model assembly: decoder-only / hybrid / ssm / encoder-decoder LMs.

Layers are grouped by the repeating ``block_pattern`` and scanned with
stacked weights (`jax.lax.scan` over groups), so HLO size — and 512-device
SPMD compile time — is independent of depth (61-layer Kimi compiles one
scanned block).  Remainder layers (pattern not dividing num_layers) are
applied unrolled.

Forward modes:
  * ``forward``       — teacher-forced logits for train / full-sequence eval.
  * ``decode_step``   — one token with carried per-layer state (KV cache,
    ring-buffer window cache, or recurrent state), O(1) per token.
  * ``prefill``       — a whole prompt CHUNK with carried state in one pass
    (serving admission): same state semantics as ``decode_step`` but S =
    chunk, with a per-slot valid-token count so prefilling and decoding
    slots coexist in a batch.  Bit-identical to S decode steps in float
    mode.
  * ``forward_capture`` — unrolled paired FLOAT/ABFP pass returning per-layer
    differential-noise samples for DNF (paper Fig. 3).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abfp import QuantConfig
from repro.core.dnf import NoiseHistogram
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.layers import (
    Numerics,
    attention_block,
    init_attention,
    init_mlp,
    mlp_block,
    norm,
    sinusoidal_positions,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _norm_params(mcfg, shape=()):
    p = {"scale": jnp.zeros(shape + (mcfg.d_model,), jnp.float32)}
    if mcfg.norm_type == "layernorm":
        p["scale"] = jnp.ones(shape + (mcfg.d_model,), jnp.float32)
        p["bias"] = jnp.zeros(shape + (mcfg.d_model,), jnp.float32)
    return p


def _init_layer(key, mcfg: ModelConfig, kind: str, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": _norm_params(mcfg)}
    if kind == "attention":
        p["attn"] = init_attention(ks[0], mcfg)
        p["norm2"] = _norm_params(mcfg)
        if mcfg.num_experts:
            p["moe"] = moe_lib.init_moe(ks[1], mcfg)
        elif mcfg.d_ff:
            p["mlp"] = init_mlp(ks[1], mcfg)
        if cross:
            p["cross"] = init_attention(ks[2], mcfg)
            p["norm3"] = _norm_params(mcfg)
    elif kind == "recurrent":
        p["rglru"] = rec_lib.init_rglru_block(ks[0], mcfg)
        p["norm2"] = _norm_params(mcfg)
        p["mlp"] = init_mlp(ks[1], mcfg)
    elif kind == "mlstm":
        p["mlstm"] = rec_lib.init_mlstm_block(ks[0], mcfg)
    elif kind == "slstm":
        p["slstm"] = rec_lib.init_slstm_block(ks[0], mcfg)
    else:
        raise ValueError(kind)
    return p


def _pattern(mcfg: ModelConfig):
    pattern = mcfg.block_pattern or ("attention",)
    n_groups = mcfg.num_layers // len(pattern)
    remainder = mcfg.num_layers % len(pattern)
    return pattern, n_groups, remainder


def init_params(key: Array, mcfg: ModelConfig) -> dict:
    pattern, n_groups, remainder = _pattern(mcfg)
    keys = jax.random.split(key, 8)

    params: dict = {
        "embed": (jax.random.normal(keys[0], (mcfg.vocab_size, mcfg.d_model))
                  * mcfg.d_model**-0.5).astype(mcfg.param_dtype),
        "final_norm": _norm_params(mcfg),
    }
    cross = mcfg.is_encoder_decoder

    # Stacked pattern groups: one sub-init per pattern position, vmapped over
    # groups so every leaf gets a leading (n_groups,) axis.
    group_params = []
    for j, kind in enumerate(pattern):
        gkeys = jax.random.split(jax.random.fold_in(keys[1], j), n_groups)
        group_params.append(
            jax.vmap(lambda k, kind=kind: _init_layer(k, mcfg, kind, cross))(gkeys))
    params["groups"] = tuple(group_params)

    extra = []
    for r in range(remainder):
        kind = pattern[r]
        extra.append(_init_layer(jax.random.fold_in(keys[2], r), mcfg, kind, cross))
    params["extra"] = tuple(extra)

    if not mcfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[3], (mcfg.d_model, mcfg.vocab_size))
            * mcfg.d_model**-0.5).astype(mcfg.param_dtype)

    if mcfg.is_encoder_decoder:
        ekeys = jax.random.split(keys[4], mcfg.num_encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: _init_layer(k, mcfg, "attention", cross=False))(ekeys),
            "final_norm": _norm_params(mcfg),
        }
    return params


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _apply_layer(
    lp: dict,
    x: Array,
    mcfg: ModelConfig,
    kind: str,
    nx: Numerics,
    *,
    positions: Array,
    state: Optional[dict] = None,
    enc_kv: Optional[tuple] = None,
    mesh=None,
    n_tokens: Optional[Array] = None,
    page_table: Optional[Array] = None,
):
    """One layer (pre-norm residual).  Returns (x, new_state, aux_loss).

    ``n_tokens`` (B,) marks the chunked-prefill path: x holds a prompt
    chunk of which only the first n_tokens[b] positions are real per slot;
    state updates for the padding (and for slots with n == 0) are no-ops.
    ``page_table`` (B, MP) routes paged KV caches (see serving.pages).
    """
    aux = jnp.float32(0.0)
    new_state: Any = None
    if kind == "attention":
        window = mcfg.window_size if mcfg.attention_type == "hybrid" else 0
        h = norm(x, lp["norm1"], mcfg.norm_type)
        attn_out, kv = attention_block(
            lp["attn"], h, mcfg, nx, positions=positions,
            window=window, kv_cache=(state or {}).get("kv"),
            train_mode=mcfg.remat, n_tokens=n_tokens,
            page_table=page_table)
        x = x + attn_out
        new_state = {"kv": kv} if kv is not None else None
        if enc_kv is not None:
            h = norm(x, lp["norm3"], mcfg.norm_type)
            cross_out, _ = attention_block(
                lp["cross"], h, mcfg, nx, positions=positions, cross_kv=enc_kv,
                train_mode=mcfg.remat)
            x = x + cross_out
        h = norm(x, lp["norm2"], mcfg.norm_type)
        if mcfg.num_experts:
            if mesh is not None:
                y, aux = moe_lib.moe_block_sharded(lp["moe"], h, mcfg, nx, mesh)
            else:
                y, aux = moe_lib.moe_block(lp["moe"], h, mcfg, nx)
        elif mcfg.d_ff:
            y = mlp_block(lp["mlp"], h, mcfg, nx)
        else:
            y = jnp.zeros_like(x)
        x = x + y
    elif kind == "recurrent":
        h = norm(x, lp["norm1"], mcfg.norm_type)
        y, st = rec_lib.rglru_block(lp["rglru"], h, mcfg, nx,
                                    state=(state or {}).get("rec"),
                                    n_tokens=n_tokens)
        x = x + y
        new_state = {"rec": st}
        h = norm(x, lp["norm2"], mcfg.norm_type)
        x = x + mlp_block(lp["mlp"], h, mcfg, nx)
    elif kind == "mlstm":
        h = norm(x, lp["norm1"], mcfg.norm_type)
        y, st = rec_lib.mlstm_block(lp["mlstm"], h, mcfg, nx,
                                    state=(state or {}).get("rec"),
                                    n_tokens=n_tokens)
        x = x + y
        new_state = {"rec": st}
    elif kind == "slstm":
        h = norm(x, lp["norm1"], mcfg.norm_type)
        y, st = rec_lib.slstm_block(lp["slstm"], h, mcfg, nx,
                                    state=(state or {}).get("rec"),
                                    n_tokens=n_tokens)
        x = x + y
        new_state = {"rec": st}
    else:
        raise ValueError(kind)
    return x, new_state, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(params, tokens_or_embeds, mcfg, positions):
    if jnp.issubdtype(tokens_or_embeds.dtype, jnp.integer):
        x = jnp.take(params["embed"], tokens_or_embeds, axis=0)
    else:
        x = tokens_or_embeds.astype(mcfg.param_dtype)  # stub frontends
    x = x.astype(mcfg.activation_dtype)
    if mcfg.embed_scale:
        x = x * jnp.asarray(mcfg.d_model**0.5, x.dtype)
    if mcfg.pos_type == "absolute":
        x = x + sinusoidal_positions(positions, mcfg.d_model).astype(x.dtype)
    return x


def _lm_head(params, x, mcfg, nx: Numerics):
    # An explicit "lm_head" entry wins even for tied embeddings: the packed
    # serving path (models.packing) inserts a pre-quantized embed.T there.
    if "lm_head" in params:
        w = params["lm_head"]
    else:
        assert mcfg.tie_embeddings
        w = params["embed"].T
    return nx.dense(x, w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Encoder (enc-dec models)
# ---------------------------------------------------------------------------


def encode(params, features: Array, mcfg, nx: Numerics) -> Array:
    """Whisper-style encoder over stub frame embeddings (B, S_enc, d)."""
    b, s, _ = features.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = features.astype(mcfg.activation_dtype)
    x = x + sinusoidal_positions(positions, mcfg.d_model).astype(x.dtype)

    def body(x, xs):
        lp, g = xs
        nxg = nx.fold(1000 + g)
        h = norm(x, lp["norm1"], mcfg.norm_type)
        attn_out, _ = attention_block(lp["attn"], h, mcfg, nxg,
                                      positions=positions, causal=False,
                                      train_mode=mcfg.remat)
        x = x + attn_out
        h = norm(x, lp["norm2"], mcfg.norm_type)
        x = x + mlp_block(lp["mlp"], h, mcfg, nxg)
        return x, None

    n_enc = mcfg.num_encoder_layers
    x, _ = jax.lax.scan(body, x, (params["encoder"]["layers"], jnp.arange(n_enc)))
    return norm(x, params["encoder"]["final_norm"], mcfg.norm_type)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(
    params: dict,
    tokens: Array,
    mcfg: ModelConfig,
    nx: Optional[Numerics] = None,
    *,
    encoder_features: Optional[Array] = None,
    dnf: Optional[NoiseHistogram] = None,
    dnf_key: Optional[Array] = None,
    mesh=None,
    return_hidden: bool = False,
):
    """Teacher-forced forward.  ``tokens``: (B, S) int ids or (B, S, d)
    stub-frontend embeddings.  Returns (logits (B, S, V) f32, aux_loss), or
    (hidden (B, S, d), aux_loss) with ``return_hidden`` (the chunked-loss
    path avoids materializing full-vocab logits)."""
    nx = nx or Numerics(QuantConfig(mode="float"))
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed(params, tokens, mcfg, positions)

    enc_kv = None
    if mcfg.is_encoder_decoder:
        assert encoder_features is not None
        enc_out = encode(params, encoder_features, mcfg, nx)
        enc_kv = _cross_kv(params, enc_out, mcfg, nx)   # per-pattern-pos, (NG,...)

    pattern, n_groups, remainder = _pattern(mcfg)
    glen = len(pattern)

    def body(carry, xs):
        x, aux = carry
        gparams, g_enc_kv, g = xs
        new_aux = aux
        for j, kind in enumerate(pattern):
            nxj = nx.fold(g * glen + j)
            lidx = g * glen + j
            ek = g_enc_kv[j] if g_enc_kv is not None else None
            x, _, a = _apply_layer(
                gparams[j], x, mcfg, kind, nxj,
                positions=positions, enc_kv=ek, mesh=mesh)
            new_aux = new_aux + a
            if dnf is not None:
                h = dnf.layer(lidx)
                key_l = jax.random.fold_in(dnf_key, lidx)
                x = x + h.sample(key_l, x.shape).astype(x.dtype)
        return (x, new_aux), None

    scan_body = jax.checkpoint(body) if mcfg.remat else body
    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.float32(0.0)),
        (params["groups"], enc_kv, jnp.arange(n_groups)))

    for r in range(remainder):
        kind = pattern[r]
        lidx = n_groups * glen + r
        # Remainder layers only occur for non-enc-dec patterns (no cross-attn).
        x, _, a = _apply_layer(
            params["extra"][r], x, mcfg, kind, nx.fold(lidx),
            positions=positions, enc_kv=None, mesh=mesh)
        aux = aux + a
        if dnf is not None:
            h = dnf.layer(lidx)
            x = x + h.sample(jax.random.fold_in(dnf_key, lidx), x.shape).astype(x.dtype)

    x = norm(x, params["final_norm"], mcfg.norm_type)
    if return_hidden:
        return x, aux
    logits = _lm_head(params, x, mcfg, nx.fold(999_983))
    return logits, aux


def lm_head_logits(params, hidden: Array, mcfg: ModelConfig,
                   nx: Optional[Numerics] = None) -> Array:
    """Project (B, S, d) hidden states to f32 logits (chunked-loss helper)."""
    nx = nx or Numerics(QuantConfig(mode="float"))
    return _lm_head(params, hidden, mcfg, nx.fold(999_983))


def encode_cross_kv(params, enc_out, mcfg, nx):
    """Public wrapper over ``_cross_kv`` for the serving path: precompute
    the cross-attention K/V a decoder consumes from an encoder output —
    the per-slot encoder cache ``serving.runners.EncDecRunner`` scatters
    into the decode state at admission."""
    return _cross_kv(params, enc_out, mcfg, nx)


def _cross_kv(params, enc_out, mcfg, nx):
    """Precompute encoder K/V per decoder layer (whisper cross-attention)."""
    b, s, _ = enc_out.shape
    kh, hd = mcfg.num_kv_heads, mcfg.resolved_head_dim

    def per_group(gparams):
        k = nx.dense(enc_out, gparams["cross"]["wk"]).reshape(b, s, kh, hd)
        v = nx.dense(enc_out, gparams["cross"]["wv"]).reshape(b, s, kh, hd)
        return k, v

    # Stacked over groups: vmap over the group axis of the params.  Returns a
    # list over pattern positions, each (k, v) with leading (n_groups,) axis.
    return [jax.vmap(per_group, in_axes=0, out_axes=0)(gp)
            for gp in params["groups"]]


# ---------------------------------------------------------------------------
# Decode (one token, carried state)
# ---------------------------------------------------------------------------


def init_decode_state(mcfg: ModelConfig, batch: int, max_len: int, *,
                      page_size: Optional[int] = None,
                      pool_pages: Optional[int] = None) -> dict:
    """Allocate per-layer decode state, stacked over scan groups.

    With ``page_size``/``pool_pages`` set, full-attention KV caches become
    PAGED: each layer holds a global ``(pool_pages, page_size, ...)`` pool
    shared by all slots, and the state gains a ``page_table`` (batch,
    max_pages) int32 leaf (initialized to the sentinel ``pool_pages``)
    mapping each slot's logical pages to physical pool pages.  Window/ring
    caches and recurrent state are never paged — the serving engine gates
    paging to append-only full-attention models.
    """
    pattern, n_groups, remainder = _pattern(mcfg)
    kh, hd = mcfg.num_kv_heads, mcfg.resolved_head_dim
    dtype = mcfg.activation_dtype
    paged = page_size is not None
    if paged:
        assert pool_pages is not None and pool_pages >= 1
        max_pages = -(-max_len // page_size)

    def one(kind):
        if kind == "attention":
            window = mcfg.window_size if mcfg.attention_type == "hybrid" else 0
            cache_len = window if window > 0 else max_len
            if paged and window == 0:
                if mcfg.kv_quant:
                    return {"kv": {
                        "k_pages": jnp.zeros(
                            (pool_pages, page_size, kh, hd), jnp.int8),
                        "v_pages": jnp.zeros(
                            (pool_pages, page_size, kh, hd), jnp.int8),
                        "k_scale_pages": jnp.zeros(
                            (pool_pages, page_size, kh), jnp.bfloat16),
                        "v_scale_pages": jnp.zeros(
                            (pool_pages, page_size, kh), jnp.bfloat16),
                        "length": jnp.zeros((batch,), jnp.int32),
                    }}
                return {"kv": {
                    "k_pages": jnp.zeros(
                        (pool_pages, page_size, kh, hd), dtype),
                    "v_pages": jnp.zeros(
                        (pool_pages, page_size, kh, hd), dtype),
                    "length": jnp.zeros((batch,), jnp.int32),
                }}
            if mcfg.kv_quant:
                # ABFP-quantized cache: int8 codes + per-(token, head) scale.
                return {"kv": {
                    "k": jnp.zeros((batch, cache_len, kh, hd), jnp.int8),
                    "v": jnp.zeros((batch, cache_len, kh, hd), jnp.int8),
                    "k_scale": jnp.zeros((batch, cache_len, kh), jnp.bfloat16),
                    "v_scale": jnp.zeros((batch, cache_len, kh), jnp.bfloat16),
                    "length": jnp.zeros((batch,), jnp.int32),
                }}
            return {"kv": {
                "k": jnp.zeros((batch, cache_len, kh, hd), dtype),
                "v": jnp.zeros((batch, cache_len, kh, hd), dtype),
                "length": jnp.zeros((batch,), jnp.int32),
            }}
        if kind == "recurrent":
            r = mcfg.lru_width or mcfg.d_model
            return {"rec": {
                "conv": jnp.zeros((batch, mcfg.conv_width - 1, r), dtype),
                "h": jnp.zeros((batch, r), jnp.float32),
            }}
        if kind == "mlstm":
            inner = 2 * mcfg.d_model
            nh = mcfg.num_heads
            dh = inner // nh
            return {"rec": {
                "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, nh, dh), jnp.float32),
                "m": jnp.zeros((batch, nh), jnp.float32),
            }}
        if kind == "slstm":
            nh = mcfg.num_heads
            dh = mcfg.d_model // nh
            z = jnp.zeros((batch, nh, dh), jnp.float32)
            return {"rec": {"h": z, "c": z, "n": z,
                            "m": jnp.full((batch, nh, dh), -1e30, jnp.float32)}}
        raise ValueError(kind)

    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    state = {
        "groups": tuple(stack(one(kind), n_groups) for kind in pattern),
        "extra": tuple(one(pattern[r]) for r in range(remainder)),
        "position": jnp.zeros((batch,), jnp.int32),
    }
    if paged:
        # Sentinel-initialized: every entry routes writes to the drop lane
        # until the engine allocates a page (serving.pages owns the host
        # mirror and refreshes this leaf before each jitted pass).
        state["page_table"] = jnp.full((batch, max_pages), pool_pages,
                                       jnp.int32)
    return state


def decode_step(
    params: dict,
    state: dict,
    token: Array,
    mcfg: ModelConfig,
    nx: Optional[Numerics] = None,
    *,
    enc_kv=None,
):
    """One decode step.  token: (B,) int32 (or (B, d) embeds).
    Returns (logits (B, V) f32, new_state).

    With ``nx.quant.mode == "abfp_fused"`` (packed weights with per-tile
    ADC gains, quantized KV cache) every full-attention layer's tick runs
    the fused QKV + attention kernels instead of the dispatch chain —
    see ``models.layers._fused_decode_attention_block`` — with identical
    PRNG threading, so greedy decode matches the packed chain bit-for-bit
    at gain 1.0."""
    nx = nx or Numerics(QuantConfig(mode="float"))
    b = token.shape[0]
    positions = state["position"][:, None]                   # (B, 1)
    pt = state.get("page_table")
    tok = token[:, None] if token.ndim == 1 else token[:, None, :]
    x = _embed(params, tok, mcfg, positions)

    pattern, n_groups, remainder = _pattern(mcfg)
    glen = len(pattern)

    def body(x, xs):
        gparams, gstate, g_enc_kv, g = xs
        new_states = []
        for j, kind in enumerate(pattern):
            nxj = nx.fold(g * glen + j)
            ek = g_enc_kv[j] if g_enc_kv is not None else None
            x, st, _ = _apply_layer(
                gparams[j], x, mcfg, kind, nxj,
                positions=positions, state=gstate[j], enc_kv=ek,
                page_table=pt)
            new_states.append(st)
        return x, tuple(new_states)

    x, new_group_states = jax.lax.scan(
        body, x,
        (params["groups"], state["groups"], enc_kv, jnp.arange(n_groups)))

    new_extra = []
    for r in range(remainder):
        kind = pattern[r]
        x, st, _ = _apply_layer(
            params["extra"][r], x, mcfg, kind, nx.fold(n_groups * glen + r),
            positions=positions, state=state["extra"][r], enc_kv=None,
            page_table=pt)
        new_extra.append(st)

    x = norm(x, params["final_norm"], mcfg.norm_type)
    logits = _lm_head(params, x, mcfg, nx.fold(999_983))[:, 0]
    new_state = {
        "groups": new_group_states,
        "extra": tuple(new_extra),
        "position": state["position"] + 1,
    }
    if pt is not None:
        new_state["page_table"] = pt
    return logits, new_state


# ---------------------------------------------------------------------------
# Chunked prefill (S = chunk generalization of decode_step)
# ---------------------------------------------------------------------------


def prefill(
    params: dict,
    state: dict,
    tokens: Array,
    n_tokens: Array,
    mcfg: ModelConfig,
    nx: Optional[Numerics] = None,
    *,
    enc_kv=None,
):
    """Advance slots by a whole prompt chunk in ONE jitted pass.

    tokens: (B, S) int32 prompt chunk per slot (padding values arbitrary);
    ``n_tokens``: (B,) int32 — tokens[b, :n_tokens[b]] are real.  A slot
    with n_tokens == 0 is left bit-for-bit untouched, so prefilling and
    decoding slots can share the batch.  Returns (logits (B, V) f32 taken
    at each slot's LAST valid token, new_state).

    Prompt admission cost drops from O(prompt_len) sequential decode ticks
    to O(prompt_len / chunk) passes whose matmuls run at M = B*S — the
    MXU-friendly shapes the packed ABFP kernel was built for.

    Numerics: in ``mode="float"`` the result is bit-identical to feeding
    the same tokens through ``decode_step`` one at a time (the projections
    batch over the chunk, while order-sensitive state updates — KV append,
    ring-buffer window attention, recurrent folds — run as scans of the
    exact decode-step ops; see tests/test_prefill.py).  ABFP modes are
    statistically equivalent only: the Pallas noise PRNG salts by grid
    position, and a chunked matmul grid differs from S decode-shaped grids.
    """
    nx = nx or Numerics(QuantConfig(mode="float"))
    b, s = tokens.shape[:2]
    positions = state["position"][:, None] + jnp.arange(s)[None, :]
    pt = state.get("page_table")
    x = _embed(params, tokens, mcfg, positions)

    pattern, n_groups, remainder = _pattern(mcfg)
    glen = len(pattern)

    def body(x, xs):
        gparams, gstate, g_enc_kv, g = xs
        new_states = []
        for j, kind in enumerate(pattern):
            nxj = nx.fold(g * glen + j)
            ek = g_enc_kv[j] if g_enc_kv is not None else None
            x, st, _ = _apply_layer(
                gparams[j], x, mcfg, kind, nxj,
                positions=positions, state=gstate[j], enc_kv=ek,
                n_tokens=n_tokens, page_table=pt)
            new_states.append(st)
        return x, tuple(new_states)

    x, new_group_states = jax.lax.scan(
        body, x,
        (params["groups"], state["groups"], enc_kv, jnp.arange(n_groups)))

    new_extra = []
    for r in range(remainder):
        kind = pattern[r]
        x, st, _ = _apply_layer(
            params["extra"][r], x, mcfg, kind, nx.fold(n_groups * glen + r),
            positions=positions, state=state["extra"][r], enc_kv=None,
            n_tokens=n_tokens, page_table=pt)
        new_extra.append(st)

    x = norm(x, params["final_norm"], mcfg.norm_type)
    last = jnp.clip(n_tokens - 1, 0, s - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)  # (B, 1, d)
    logits = _lm_head(params, x_last, mcfg, nx.fold(999_983))[:, 0]
    new_state = {
        "groups": new_group_states,
        "extra": tuple(new_extra),
        "position": state["position"] + n_tokens,
    }
    if pt is not None:
        new_state["page_table"] = pt
    return logits, new_state


# ---------------------------------------------------------------------------
# On-device sampling (overlapped serving keeps tokens as device arrays)
# ---------------------------------------------------------------------------


def sample_tokens(
    logits: Array,
    temperatures: Array,
    uids: Array,
    token_idxs: Array,
    seed: int,
) -> Array:
    """Sample one next token per batch row ON DEVICE.

    logits: (B, V) f32; temperatures: (B,) f32; uids / token_idxs: (B,)
    int32.  Rows with ``temperature == 0`` decode greedily — ``jnp.argmax``
    breaks ties at the first occurrence exactly like ``np.argmax``, so
    greedy device sampling is bit-identical to the host path.  Rows with
    ``temperature > 0`` draw from the temperature-scaled softmax using a
    per-row stream keyed by ``(seed, uid, token_idx)`` (a jax PRNG
    ``fold_in`` chain), so draws are reproducible for a given engine seed
    no matter how the scheduler interleaves requests across ticks — the
    same contract as the host sampler, though the two PRNGs draw different
    (equally valid) samples.  Returns (B,) int32."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def draw(row, t, uid, idx):
        k = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), uid), idx)
        safe_t = jnp.where(t > 0, t, 1.0)
        return jax.random.categorical(k, row / safe_t).astype(jnp.int32)

    sampled = jax.vmap(draw)(logits, temperatures, uids, token_idxs)
    return jnp.where(temperatures > 0, sampled, greedy)


# ---------------------------------------------------------------------------
# DNF paired capture (unrolled; smoke/finetune scale)
# ---------------------------------------------------------------------------


def forward_capture(
    params: dict,
    tokens: Array,
    mcfg: ModelConfig,
    nx_float: Numerics,
    nx_abfp_factory,
    *,
    encoder_features=None,
):
    """Paper Fig. 3: run each layer in FLOAT on the FLOAT stream, also run the
    ABFP version of the layer on the SAME input, and collect dy = ABFP - FLOAT
    per layer.  Unrolled (python loop) — used once, on one batch.

    ``nx_abfp_factory()`` must return a fresh ABFP Numerics per layer call.
    Returns (logits, [dy_1, ..., dy_L]) with dy in f32.
    """
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed(params, tokens, mcfg, positions)

    enc_kv = None
    if mcfg.is_encoder_decoder:
        enc_out = encode(params, encoder_features, mcfg, nx_float)
        enc_kv = _cross_kv(params, enc_out, mcfg, nx_float)

    pattern, n_groups, remainder = _pattern(mcfg)
    glen = len(pattern)
    deltas = []

    def layer_at(j, g):
        return jax.tree.map(lambda p: p[g], params["groups"][j])

    for g in range(n_groups):
        for j, kind in enumerate(pattern):
            lidx = g * glen + j
            lp = layer_at(j, g)
            ek = enc_kv[j] if enc_kv is not None else None
            ekg = jax.tree.map(lambda a: a[g], ek) if ek is not None else None
            x_f, _, _ = _apply_layer(lp, x, mcfg, kind, nx_float.fold(lidx),
                                     positions=positions, enc_kv=ekg)
            x_q, _, _ = _apply_layer(lp, x, mcfg, kind,
                                     nx_abfp_factory().fold(lidx),
                                     positions=positions, enc_kv=ekg)
            deltas.append((x_q.astype(jnp.float32) - x_f.astype(jnp.float32)))
            x = x_f                                           # FLOAT stream
    for r in range(remainder):
        kind = pattern[r]
        lidx = n_groups * glen + r
        lp = params["extra"][r]
        x_f, _, _ = _apply_layer(lp, x, mcfg, kind, nx_float.fold(lidx),
                                 positions=positions, enc_kv=None)
        x_q, _, _ = _apply_layer(lp, x, mcfg, kind, nx_abfp_factory().fold(lidx),
                                 positions=positions, enc_kv=None)
        deltas.append((x_q.astype(jnp.float32) - x_f.astype(jnp.float32)))
        x = x_f

    x = norm(x, params["final_norm"], mcfg.norm_type)
    logits = _lm_head(params, x, mcfg, nx_float.fold(999_983))
    return logits, deltas
