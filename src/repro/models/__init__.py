"""repro.models — composable model zoo with ABFP-dispatched matmuls.

Serving ownership (family -> ModelRunner, see ``repro.serving.runners``):
decoder-only dense/MoE families (smollm, tinyllama, gemma, chatglm,
granite, kimi, phi-vision) serve through ``DecoderRunner``; ssm/hybrid
families (xlstm, recurrentgemma) through ``RecurrentRunner`` (fixed-size
decode state — no paging, no preemption); encoder-decoder families
(whisper) through ``EncDecRunner`` (one ``encode`` +
``encode_cross_kv`` pass at admission, cached per slot).  Model code
stays engine-agnostic: ``decode_step`` / ``prefill`` take an optional
``enc_kv`` and never import serving.
"""

from repro.models.layers import (  # noqa: F401
    Numerics,
    attention_block,
    chunked_attention,
    decode_attention,
    im2col,
    layernorm,
    mlp_block,
    rmsnorm,
    rope,
)
from repro.models.lm import (  # noqa: F401
    decode_step,
    encode,
    encode_cross_kv,
    forward,
    forward_capture,
    init_decode_state,
    init_params,
    param_count,
    prefill,
    sample_tokens,
)
from repro.models.packing import (  # noqa: F401
    pack_model_params,
    packed_param_bytes,
)
from repro.models import frontends, moe, recurrent  # noqa: F401
