"""repro.models — composable model zoo with ABFP-dispatched matmuls."""

from repro.models.layers import (  # noqa: F401
    Numerics,
    attention_block,
    chunked_attention,
    decode_attention,
    im2col,
    layernorm,
    mlp_block,
    rmsnorm,
    rope,
)
from repro.models.lm import (  # noqa: F401
    decode_step,
    encode,
    forward,
    forward_capture,
    init_decode_state,
    init_params,
    param_count,
    prefill,
)
from repro.models.packing import (  # noqa: F401
    pack_model_params,
    packed_param_bytes,
)
from repro.models import frontends, moe, recurrent  # noqa: F401
