"""Recurrent temporal-mixing blocks: RG-LRU (Griffin / recurrentgemma),
mLSTM and sLSTM (xLSTM).

Projections (input/gate/output linears) run through ``Numerics.dense`` so
ABFP applies to them; the recurrence internals are elementwise / gated state
updates — range-sensitive, so they stay in digital FLOAT32, exactly the
paper's rule for norm-like ops (DESIGN.md §Arch-applicability).

Training/prefill uses parallel forms where the math allows:
  * RG-LRU — ``jax.lax.associative_scan`` over the linear recurrence.
  * mLSTM  — chunkwise linear attention with log-space gate stabilization.
  * sLSTM  — inherently sequential (recurrent weights inside the gates);
    ``jax.lax.scan`` over time.
Decode is a single recurrent step with a constant-size carried state — this
is what makes the long_500k shape servable for ssm/hybrid archs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Numerics

Array = jax.Array


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_block(key, mcfg, layer_shape=()) -> dict:
    d = mcfg.d_model
    r = mcfg.lru_width or d
    ks = jax.random.split(key, 7)
    shape = lambda *s: layer_shape + s  # noqa: E731
    init = lambda k, fan_in, *s: (  # noqa: E731
        jax.random.normal(k, shape(*s)) * fan_in**-0.5).astype(mcfg.param_dtype)
    # Lambda init so a = sigmoid(lam)^c is in ~[0.9, 0.999] (Griffin A.2).
    u = jax.random.uniform(ks[6], shape(r), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(u ** (1.0 / _RGLRU_C) / (1 - u ** (1.0 / _RGLRU_C)))
    return {
        "w_in": init(ks[0], d, d, r),
        "w_gate": init(ks[1], d, d, r),
        "conv_w": (jax.random.normal(ks[2], shape(mcfg.conv_width, r))
                   * mcfg.conv_width**-0.5).astype(mcfg.param_dtype),
        "w_rg": init(ks[3], r, r, r),       # recurrence gate
        "w_ig": init(ks[4], r, r, r),       # input gate
        "w_out": init(ks[5], r, r, d),
        "lam": lam.astype(jnp.float32),
    }


def _causal_depthwise_conv(u: Array, w: Array, state: Optional[Array],
                           n_tokens: Optional[Array] = None):
    """u: (B, S, R), w: (W, R) depthwise causal conv.  ``state``: last W-1
    inputs from the previous call (decode).  Returns (out, new_state).

    ``n_tokens`` (chunked prefill): only the first n_tokens[b] positions of
    u are real; the carried tail is then the last W-1 inputs of the VALID
    prefix (per-slot gather), so a slot with n == 0 keeps its state exactly.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)               # (B, W-1+S, R)
    out = sum(
        ext[:, i : i + u.shape[1]] * w[i][None, None] for i in range(width)
    )
    if width == 1:
        new_state = state
    elif n_tokens is None:
        new_state = ext[:, -(width - 1):]
    else:
        idx = n_tokens[:, None] + jnp.arange(width - 1)[None, :]  # (B, W-1)
        new_state = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
    return out, new_state


def rglru_block(params, x: Array, mcfg, nx: Numerics,
                state: Optional[dict] = None,
                n_tokens: Optional[Array] = None):
    """Griffin recurrent block.  Returns (y, new_state); state carries the
    conv tail and the LRU hidden h — O(1) memory per token (long-context).

    ``n_tokens`` (B,) selects the chunked-prefill path: the projections run
    batched over the chunk while the h recurrence folds SEQUENTIALLY (same
    per-step op as decode, so the carried state is bit-identical to feeding
    the chunk token by token); positions >= n_tokens[b] leave slot b's
    state untouched.
    """
    gate = jax.nn.gelu(nx.dense(x, params["w_gate"]).astype(jnp.float32))
    u = nx.dense(x, params["w_in"])

    conv_state = state["conv"] if state else None
    u, new_conv = _causal_depthwise_conv(u, params["conv_w"], conv_state,
                                         n_tokens=n_tokens)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(nx.dense(u, params["w_rg"]).astype(jnp.float32))
    i = jax.nn.sigmoid(nx.dense(u, params["w_ig"]).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r    # (B, S, R)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    h0 = state["h"] if state else None
    if n_tokens is not None:
        assert h0 is not None, "chunked prefill needs a carried state"
        valid = jnp.arange(x.shape[1])[:, None] < n_tokens[None, :]  # (S, B)

        def stepf(h, xs):
            a_t, b_t, ok = xs
            h = jnp.where(ok[:, None], a_t * h + b_t, h)      # decode-step op
            return h, h

        h, hs = jax.lax.scan(
            stepf, h0,
            (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0), valid))
        hs = jnp.moveaxis(hs, 0, 1)
    elif x.shape[1] == 1 and h0 is not None:
        h = a[:, 0] * h0 + b[:, 0]                            # decode step
        hs = h[:, None]
    else:
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0)
        # h_t = a_t h_{t-1} + b_t  via associative scan over S.
        def op(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, hs = jax.lax.associative_scan(op, (a, b), axis=1)
        h = hs[:, -1]

    y = nx.dense((hs * gate).astype(x.dtype), params["w_out"])
    return y, {"conv": new_conv, "h": h}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — chunkwise parallel linear attention with exp gates
# ---------------------------------------------------------------------------


def init_mlstm_block(key, mcfg, layer_shape=()) -> dict:
    d = mcfg.d_model
    inner = 2 * d                                   # xLSTM pf=2 up-projection
    nh = mcfg.num_heads
    ks = jax.random.split(key, 8)
    shape = lambda *s: layer_shape + s  # noqa: E731
    init = lambda k, fan, *s: (  # noqa: E731
        jax.random.normal(k, shape(*s)) * fan**-0.5).astype(mcfg.param_dtype)
    return {
        "w_up": init(ks[0], d, d, inner),
        "w_gate": init(ks[1], d, d, inner),
        "wq": init(ks[2], inner, inner, inner),
        "wk": init(ks[3], inner, inner, inner),
        "wv": init(ks[4], inner, inner, inner),
        "w_if": init(ks[5], inner, inner, 2 * nh),  # input+forget gate logits
        "w_down": init(ks[6], inner, inner, d),
        "skip_scale": jnp.zeros(shape(inner), jnp.float32),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, state, chunk, valid=None):
    """Chunkwise stabilized mLSTM.  q,k,v: (B, NH, S, D); gates (B, NH, S).
    state: (C (B,NH,D,D), n (B,NH,D), m (B,NH)).  Returns (h, new_state).

    ``valid`` (B, S) bool requires chunk == 1 (each scan step is then one
    token): steps with valid False leave the carried state unchanged —
    the chunked-prefill padding semantics.
    """
    b, nh, s, dh = q.shape
    assert valid is None or chunk == 1, "valid mask needs chunk == 1"
    pad = (-s) % chunk
    if pad:
        padf = lambda a, fill=0.0: jnp.pad(  # noqa: E731
            a, [(0, 0)] * (a.ndim - 1) + [(0, pad)] if a.ndim == 3 else
            [(0, 0), (0, 0), (0, pad), (0, 0)], constant_values=fill)
        q, k, v = padf(q), padf(k), padf(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
    sp = s + pad
    nc = sp // chunk
    if valid is None:
        cvalid = jnp.ones((nc, b), bool)
    else:
        cvalid = jnp.moveaxis(valid.reshape(b, nc, chunk)[..., 0], 1, 0)
    # (NC, B, NH, c, D) chunked views.
    cq = jnp.moveaxis(q.reshape(b, nh, nc, chunk, dh), 2, 0)
    ck = jnp.moveaxis(k.reshape(b, nh, nc, chunk, dh), 2, 0)
    cv = jnp.moveaxis(v.reshape(b, nh, nc, chunk, dh), 2, 0)
    cli = jnp.moveaxis(log_i.reshape(b, nh, nc, chunk), 2, 0)
    clf = jnp.moveaxis(log_f.reshape(b, nh, nc, chunk), 2, 0)

    def step(carry, xs):
        cmat, n, m = carry                         # (B,NH,D,D),(B,NH,D),(B,NH)
        qc, kc, vc, li, lf, ok = xs
        csum = jnp.cumsum(lf, axis=-1)             # (B, NH, c)
        total = csum[..., -1]
        # Decay from chunk start to position t (inclusive of f_t).
        # Inter-chunk stabilizer: m_inter[t] = csum[t] + m_prev.
        m_inter = csum + m[..., None]
        # Intra-chunk log weights: A[t, s] = csum[t] - csum[s] + li[s], s <= t.
        a_log = csum[..., :, None] - csum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        a_log = jnp.where(tri[None, None], a_log, -1e30)
        m_intra = jnp.max(a_log, axis=-1)          # (B, NH, c)
        m_new = jnp.maximum(m_inter, m_intra)      # running max per position
        # Stabilized weights.
        a = jnp.exp(a_log - m_new[..., None])      # (B, NH, c, c)
        inter_w = jnp.exp(m_inter - m_new)         # (B, NH, c)
        # Output: inter-chunk (state) + intra-chunk contributions.
        h_inter = jnp.einsum("bhcd,bhde->bhce", qc, cmat) * inter_w[..., None]
        n_inter = jnp.einsum("bhcd,bhd->bhc", qc, n) * inter_w
        scores = jnp.einsum("bhcd,bhsd->bhcs", qc, kc) * (dh ** -0.5)
        h_intra = jnp.einsum("bhcs,bhcs,bhse->bhce", scores, a, vc)
        n_intra = jnp.einsum("bhcs,bhcs->bhc", scores, a)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_new)) + 1e-6
        h = (h_inter + h_intra) / denom[..., None]
        # State update to end of chunk (stabilized by m_end).
        m_end = jnp.maximum(total + m, jnp.max(csum[..., -1:] - csum + li,
                                               axis=-1))
        decay_state = jnp.exp(total + m - m_end)   # (B, NH)
        k_w = jnp.exp(total[..., None] - csum + li - m_end[..., None])
        cmat_new = cmat * decay_state[..., None, None] + jnp.einsum(
            "bhs,bhsd,bhse->bhde", k_w, kc * (dh ** -0.5), vc)
        n_new = n * decay_state[..., None] + jnp.einsum(
            "bhs,bhsd->bhd", k_w, kc * (dh ** -0.5))
        sel = lambda new, old: jnp.where(  # noqa: E731
            ok.reshape((b,) + (1,) * (new.ndim - 1)), new, old)
        return (sel(cmat_new, cmat), sel(n_new, n), sel(m_end, m)), h

    new_state, hs = jax.lax.scan(step, state, (cq, ck, cv, cli, clf, cvalid))
    h = jnp.moveaxis(hs, 0, 2).reshape(b, nh, sp, dh)[:, :, :s]
    return h, new_state


def mlstm_block(params, x: Array, mcfg, nx: Numerics,
                state: Optional[dict] = None, chunk: int = 128,
                n_tokens: Optional[Array] = None):
    """xLSTM mLSTM block.  Returns (y, new_state).

    ``n_tokens`` (B,) selects the chunked-prefill path: projections batched
    over the chunk, state update run at chunk=1 (one token per scan step —
    the same arithmetic as a decode tick, so the carried state is
    bit-identical to token-by-token), padding positions masked out.
    """
    b, s, d = x.shape
    nh = mcfg.num_heads
    up = nx.dense(x, params["w_up"])
    gate = jax.nn.silu(nx.dense(x, params["w_gate"]).astype(jnp.float32))
    inner = up.shape[-1]
    dh = inner // nh

    q = nx.dense(up, params["wq"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = nx.dense(up, params["wk"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    v = nx.dense(up, params["wv"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    gl = nx.dense(up, params["w_if"]).astype(jnp.float32)     # (B, S, 2NH)
    log_i = gl[..., :nh].transpose(0, 2, 1)                   # (B, NH, S)
    log_f = jax.nn.log_sigmoid(gl[..., nh:]).transpose(0, 2, 1)

    if state is None:
        state = {
            "C": jnp.zeros((b, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((b, nh, dh), jnp.float32),
            "m": jnp.zeros((b, nh), jnp.float32),
        }
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    if n_tokens is not None:
        chunk_eff, valid = 1, jnp.arange(s)[None, :] < n_tokens[:, None]
    else:
        chunk_eff, valid = min(chunk, max(s, 1)), None
    h, (c_new, n_new, m_new) = _mlstm_chunk_scan(
        qf, kf, vf, log_i, log_f,
        (state["C"], state["n"], state["m"]), chunk_eff, valid)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, inner)
    h = h + (params["skip_scale"][None, None].astype(jnp.float32)
             * up.astype(jnp.float32))
    y = nx.dense((h * gate).astype(x.dtype), params["w_down"])
    return y, {"C": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential scalar-memory recurrence
# ---------------------------------------------------------------------------


def init_slstm_block(key, mcfg, layer_shape=()) -> dict:
    d = mcfg.d_model
    nh = mcfg.num_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    shape = lambda *s: layer_shape + s  # noqa: E731
    # 4 gates (i, f, z, o) from input and recurrent (block-diagonal) paths.
    return {
        "w_x": (jax.random.normal(ks[0], shape(d, 4 * d)) * d**-0.5
                ).astype(mcfg.param_dtype),
        "r_h": (jax.random.normal(ks[1], shape(nh, dh, 4 * dh)) * dh**-0.5
                ).astype(mcfg.param_dtype),
        "b": jnp.zeros(shape(4 * d), jnp.float32),
        # GeGLU projection pair: up to 2*(4d/3)-ish — we use 2d split into two
        # d-wide halves (gate, value), down from d.
        "w_up": (jax.random.normal(ks[2], shape(d, 2 * d)) * d**-0.5
                 ).astype(mcfg.param_dtype),
        "w_down": (jax.random.normal(ks[3], shape(d, d)) * d**-0.5
                   ).astype(mcfg.param_dtype),
    }


def slstm_block(params, x: Array, mcfg, nx: Numerics,
                state: Optional[dict] = None,
                n_tokens: Optional[Array] = None):
    """xLSTM sLSTM block with exp input gate and stabilizer state.
    Sequential over time (recurrent gate weights).  Returns (y, new_state).

    ``n_tokens`` (B,): chunked-prefill padding mask — steps at or past
    n_tokens[b] leave slot b's state unchanged (the scan is already the
    decode-step fold, so chunked state == token-by-token state bitwise).
    """
    b, s, d = x.shape
    nh = mcfg.num_heads
    dh = d // nh

    gx = nx.dense(x, params["w_x"]).astype(jnp.float32) \
        + params["b"][None, None]                            # (B, S, 4d)
    r_h = params["r_h"].astype(jnp.float32)                  # (NH, dh, 4dh)

    if state is None:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        state = {"h": zeros, "c": zeros,
                 "n": jnp.zeros((b, nh, dh), jnp.float32),
                 "m": jnp.full((b, nh, dh), -1e30, jnp.float32)}

    def step(carry, xs):
        gx_t, ok = xs
        h, c, n, m = carry                                   # (B, NH, dh)
        rec = jnp.einsum("bhd,hde->bhe", h, r_h)             # (B, NH, 4dh)
        g = gx_t.reshape(b, nh, 4 * dh) + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m, gi)                   # stabilizer
        i = jnp.exp(gi - m_new)
        f = jnp.exp(log_f + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        sel = lambda new, old: jnp.where(ok[:, None, None], new, old)  # noqa: E731
        return (sel(h_new, h), sel(c_new, c), sel(n_new, n),
                sel(m_new, m)), h_new

    gx_t = jnp.moveaxis(gx, 1, 0)                            # (S, B, 4d)
    valid = (jnp.arange(s)[:, None] < n_tokens[None, :]
             if n_tokens is not None else jnp.ones((s, b), bool))
    (h, c, n, m), hs = jax.lax.scan(
        step, (state["h"], state["c"], state["n"], state["m"]),
        (gx_t, valid))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)

    up = nx.dense(hs, params["w_up"])
    u1, u2 = jnp.split(up, 2, axis=-1)
    y = nx.dense((jax.nn.gelu(u1.astype(jnp.float32)).astype(x.dtype) * u2),
                 params["w_down"])
    return y, {"h": h, "c": c, "n": n, "m": m}
