"""Composable model layers.

Every weight-activation matmul is routed through ``Numerics.dense`` so the
whole zoo runs in FLOAT, ABFP-simulated (QAT forward), or Pallas-kernel mode
with one switch — ABFP as a first-class framework feature.

Norms, softmax, nonlinearities and the recurrent cell internals run in
FLOAT32, per the paper (Sec. V: range-sensitive ops stay digital).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.abfp import PackedWeight, QuantConfig
from repro.kernels.ops import dense as quant_dense
from repro.kernels.ops import dense_packed, dense_tp, tp_size

Array = jax.Array


# ---------------------------------------------------------------------------
# Numerics context: quant mode + PRNG threading for AMS noise
# ---------------------------------------------------------------------------


class Numerics:
    """Per-forward numerics state.

    Each ``dense`` call site gets a deterministic PRNG stream derived from
    (base key, call counter); the caller folds the layer index into the base
    key inside scan-over-layers, so streams are unique per (layer, call).

    ``mesh``: when given (sharded serving), every 2-D dense weight is
    dispatched column-parallel over the mesh's 'model' axis via
    ``kernels.ops.dense_tp`` — bit-identical to the single-device path at
    any mesh shape (noise salts are globalized per column shard).  Weights
    the mesh cannot split evenly fall back to replicated execution inside
    the same dispatch.
    """

    def __init__(self, quant: QuantConfig, key: Optional[Array] = None,
                 mesh=None):
        self.quant = quant
        self._key = key
        self.mesh = mesh
        self._count = 0

    def fold(self, idx) -> "Numerics":
        key = None if self._key is None else jax.random.fold_in(self._key, idx)
        return Numerics(self.quant, key, self.mesh)

    def dense(self, x: Array, w) -> Array:
        key = None
        if self._key is not None and self.quant.noise_lsb > 0.0 \
                and self.quant.mode != "float":
            key = jax.random.fold_in(self._key, self._count)
        self._count += 1
        if self.mesh is not None and tp_size(self.mesh) > 1:
            # Sharded serving: column-parallel tensor parallelism (with
            # replicated fallback for unsplittable weights) in one dispatch.
            return dense_tp(x, w, self.quant, key, self.mesh)
        if isinstance(w, PackedWeight):
            # Quantize-once serving path: the weight was packed at engine
            # init (pack_model_params); skip re-quantization entirely.
            return dense_packed(x, w, self.quant, key)
        return quant_dense(x, w, self.quant, key)


FLOAT_NUMERICS = lambda: Numerics(QuantConfig(mode="float"))  # noqa: E731


# ---------------------------------------------------------------------------
# Norms (digital FLOAT32)
# ---------------------------------------------------------------------------


def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(dtype)


def norm(x: Array, params: dict, kind: str) -> Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# Positions: RoPE (full / partial "2d") and absolute sinusoidal
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float, fraction: float) -> Array:
    """x: (B, S, H, D); positions: (B, S).  fraction < 1 rotates only the
    first fraction*D dims (chatglm's 2d/partial rotary)."""
    d = x.shape[-1]
    rot_d = int(d * fraction)
    rot_d -= rot_d % 2
    if rot_d == 0:
        return x
    x_rot, x_pass = x[..., :rot_d], x[..., rot_d:]
    half = rot_d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq    # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1 = x_rot[..., :half].astype(jnp.float32)
    x2 = x_rot[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(positions: Array, d: int) -> Array:
    """Sinusoidal PE evaluated at (possibly traced) positions (B, S) -> (B, S, d)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos / jnp.power(10_000.0, dim / d)                 # (B, S, d/2)
    pe = jnp.zeros(positions.shape + (d,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# Attention (chunked online-softmax — bounded memory at 32k prefill)
# ---------------------------------------------------------------------------


def _repeat_kv(k: Array, num_heads: int) -> Array:
    """(B, S, KH, D) -> (B, S, H, D) for GQA/MQA."""
    kh = k.shape[2]
    if kh == num_heads:
        return k
    return jnp.repeat(k, num_heads // kh, axis=2)


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: Array | int = 0,
    chunk: int = 512,
) -> Array:
    """Flash-semantics attention in pure JAX: scan over KV chunks with an
    online softmax, so peak memory is O(B*H*Sq*chunk) instead of O(B*H*Sq*Skv).

    q: (B, Sq, H, D); k, v: (B, Skv, KH, D).  ``window`` > 0 restricts keys to
    the last ``window`` positions (sliding-window / local attention).
    ``q_offset``: global position of q[0] (decode / chunked prefill).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)

    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nchunks = (skv + pad) // chunk

    scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)                       # (Sq,)

    kc = k.reshape(b, nchunks, chunk, h, d).astype(jnp.float32)
    vc = v.reshape(b, nchunks, chunk, h, d).astype(jnp.float32)
    kc = jnp.moveaxis(kc, 1, 0)                             # (C, B, c, H, D)
    vc = jnp.moveaxis(vc, 1, 0)

    neg = jnp.float32(-1e30)

    def step(carry, xs):
        m, den, acc = carry
        k_c, v_c, t = xs
        kpos = t * chunk + jnp.arange(chunk)                # (c,)
        s = jnp.einsum("bshd,bchd->bhsc", qf, k_c)          # (B, H, Sq, c)
        valid = kpos[None, :] < skv
        if causal:
            valid = valid & (kpos[None, :] <= q_pos[:, None])
        if window > 0:
            valid = valid & (kpos[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        den_new = den * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhsc,bchd->bhsd", p, v_c)
        return (m_new, den_new, acc_new), None

    m0 = jnp.full((b, h, sq), neg, jnp.float32)
    den0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, den, acc), _ = jax.lax.scan(
        step, (m0, den0, a0), (kc, vc, jnp.arange(nchunks)))

    out = acc / jnp.maximum(den, 1e-30)[..., None]          # (B, H, Sq, D)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)          # (B, Sq, H, D)


def train_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
) -> Array:
    """Training-path attention: scan over QUERY chunks with a rematerialized
    body.  Backward recomputes each chunk's (qc, Skv) scores instead of
    storing all of them — the flash-attention memory profile in pure JAX.
    (The KV-chunk online-softmax path in ``chunked_attention`` is ideal for
    inference but its scan carry makes backward storage O(S/c * B*H*S*D).)
    """
    b, s, h, d = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h).astype(jnp.float32)
    v = _repeat_kv(v, h).astype(jnp.float32)
    q_chunk = min(q_chunk, s)
    if s % q_chunk:
        q_chunk = s
    nq = s // q_chunk
    scale = d ** -0.5
    qc_all = jnp.moveaxis(
        (q.astype(jnp.float32) * scale).reshape(b, nq, q_chunk, h, d), 1, 0)
    kpos = jnp.arange(skv)

    def chunk_body(carry, xs):
        qc, idx = xs                                      # (B, qc, H, D)
        s_ = jnp.einsum("bqhd,bkhd->bhqk", qc, k)         # (B, H, qc, Skv)
        qpos = idx * q_chunk + jnp.arange(q_chunk)
        valid = jnp.ones((q_chunk, skv), bool)
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            valid = valid & (kpos[None, :] > qpos[:, None] - window)
        s_ = jnp.where(valid[None, None], s_, -1e30)
        p = jax.nn.softmax(s_, axis=-1)
        out_c = jnp.einsum("bhqk,bkhd->bqhd", p, v)
        return carry, out_c

    _, outs = jax.lax.scan(jax.checkpoint(chunk_body), None,
                           (qc_all, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)    # (B, S, H, D)
    return out.astype(q.dtype)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    *,
    lengths: Array,
) -> Array:
    """One-token attention against a KV cache.

    q: (B, 1, H, D); caches: (B, S_max, KH, D); ``lengths``: (B,) number of
    valid cache positions.

    Ring-buffer (sliding-window) caches need no extra masking here: the
    buffer is S_max == window wide and holds exactly the last
    ``min(length, window)`` tokens — every filled slot is in-window by
    construction, so validity is ``pos < lengths`` in both layouts
    (``lengths`` is the filled-slot count, clamped to S_max by the caller).
    """
    b, _, h, d = q.shape
    s_max = k_cache.shape[1]
    k = _repeat_kv(k_cache, h).astype(jnp.float32)
    v = _repeat_kv(v_cache, h).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bshd,bchd->bhsc", qf, k)[:, :, 0]       # (B, H, S_max)
    pos = jnp.arange(s_max)[None, :]
    valid = pos < lengths[:, None]
    s = jnp.where(valid[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhc,bchd->bhd", p, v)
    return out[:, None].astype(q.dtype)                     # (B, 1, H, D)


# ---------------------------------------------------------------------------
# ABFP-quantized KV cache (beyond-paper: the paper's per-vector adaptive
# scaling applied to the decode memory bottleneck)
# ---------------------------------------------------------------------------


def _kv_encode(v: Array):
    """(B, KH, D) -> int8 codes + per-(B, KH) bf16 scale (head_dim = tile)."""
    vf = v.astype(jnp.float32)
    s = jnp.max(jnp.abs(vf), axis=-1)                        # (B, KH)
    s = s.astype(jnp.bfloat16).astype(jnp.float32)
    s_safe = jnp.where(s == 0.0, 1.0, s)
    codes = jnp.clip(jnp.round(vf / s_safe[..., None] * 127.0), -127, 127)
    return codes.astype(jnp.int8), s.astype(jnp.bfloat16)


def _kv_decode(codes: Array, scales: Array, dtype) -> Array:
    """(B, S, KH, D) int8 + (B, S, KH) scales -> dequantized cache."""
    return (codes.astype(jnp.float32)
            * (scales.astype(jnp.float32) / 127.0)[..., None]).astype(dtype)


def quantized_decode_attention(
    q: Array,
    k_codes: Array, k_scale: Array,
    v_codes: Array, v_scale: Array,
    *,
    lengths: Array,
) -> Array:
    """Decode attention directly on int8 KV codes (perf iteration 2 of the
    memory-bound decode cell): the per-position scale factors out of the
    dot product —

        q . k_t = (q . codes_t) * s_t / 127

    so the cache is read ONCE as int8 (+ tiny scale vectors) instead of
    int8-read + bf16-write + bf16-read of a dequantized copy.  Same math as
    dequantize-then-attend up to f32 rounding.
    """
    b, _, h, d = q.shape
    s_max = k_codes.shape[1]
    kh = k_codes.shape[2]
    rep = h // kh
    qf = q.astype(jnp.float32) * (d ** -0.5)                 # (B, 1, H, D)
    qg = qf.reshape(b, kh, rep, d)                            # group by KV head
    kc = k_codes.astype(jnp.float32)                          # int8 -> f32 codes
    # codes layout (B, S, KH, D): contract D per kv head
    s = jnp.einsum("bgrd,bsgd->bgrs", qg, kc)                 # (B, KH, rep, S)
    s = s * (k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
             / 127.0)
    pos = jnp.arange(s_max)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)                            # (B, KH, rep, S)
    pv = p * (v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
              / 127.0)
    out = jnp.einsum("bgrs,bsgd->bgrd", pv, v_codes.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked-prefill attention: append a whole prompt chunk to the cache and
# attend every chunk query at its own position in one pass.
# ---------------------------------------------------------------------------


def chunk_cache_attention(q: Array, k_cache: Array, v_cache: Array,
                          *, q_pos: Array) -> Array:
    """S-query attention against a (non-ring) cache buffer.

    q: (B, S, H, D); caches: (B, S_max, KH, D); ``q_pos``: (B, S) global
    position of each query — query (b, t) attends cache slots <= q_pos[b, t].
    Mirrors ``decode_attention``'s einsum layout (scores contract head_dim,
    PV contracts the full S_max buffer with masked p == 0) so each query row
    is bit-identical to the decode tick that would have produced it.
    """
    b, s, h, d = q.shape
    s_max = k_cache.shape[1]
    k = _repeat_kv(k_cache, h).astype(jnp.float32)
    v = _repeat_kv(v_cache, h).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (d ** -0.5)
    sc = jnp.einsum("bshd,bchd->bhsc", qf, k)               # (B, H, S, S_max)
    mask = jnp.arange(s_max)[None, None, :] <= q_pos[:, :, None]
    sc = jnp.where(mask[:, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhsc,bchd->bhsd", p, v)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)          # (B, S, H, D)


def quantized_chunk_attention(
    q: Array,
    k_codes: Array, k_scale: Array,
    v_codes: Array, v_scale: Array,
    *,
    q_pos: Array,
) -> Array:
    """Chunked-prefill attention directly on int8 KV codes — the S-query
    generalization of ``quantized_decode_attention`` (same per-position
    scale factoring, same einsum layout per query row)."""
    b, s, h, d = q.shape
    s_max = k_codes.shape[1]
    kh = k_codes.shape[2]
    rep = h // kh
    qf = q.astype(jnp.float32) * (d ** -0.5)                 # (B, S, H, D)
    qg = qf.reshape(b, s, kh, rep, d)
    kc = k_codes.astype(jnp.float32)
    sc = jnp.einsum("bsgrd,bcgd->bgrsc", qg, kc)             # (B, KH, rep, S, C)
    sc = sc * (k_scale.astype(jnp.float32).transpose(0, 2, 1)
               [:, :, None, None, :] / 127.0)
    mask = (jnp.arange(s_max)[None, None, :]
            <= q_pos[:, :, None])                            # (B, S, C)
    sc = jnp.where(mask[:, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)                          # (B, KH, rep, S, C)
    pv = p * (v_scale.astype(jnp.float32).transpose(0, 2, 1)
              [:, :, None, None, :] / 127.0)
    out = jnp.einsum("bgrsc,bcgd->bsgrd", pv, v_codes.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def _append_attend_one(q: Array, k: Array, v: Array, kv_cache: dict,
                       window: int):
    """Append ONE token's K/V and attend — the decode-tick attention core.

    q: (B, 1, H, D); k, v: (B, 1, KH, D).  Shared by the S=1 decode path and
    the ring-buffer chunk scan, so both run the same ops (bit-identical by
    construction).  Returns (out (B, 1, H, D), new_cache).
    """
    b = q.shape[0]
    s_max = kv_cache["k"].shape[1]
    length = kv_cache["length"]                         # (B,)
    slot = (length % s_max) if window > 0 else length   # ring for window
    bidx = jnp.arange(b)
    quantized = "k_scale" in kv_cache
    filled = jnp.minimum(length + 1, s_max) if window > 0 else length + 1
    if quantized:
        # ABFP-quantized cache (beyond-paper, DESIGN.md): int8 codes +
        # per-(token, head) max-abs scale over the head_dim vector.
        # Attention runs directly on the codes (no dequantized copy).
        kc, ks = _kv_encode(k[:, 0])
        vc, vs = _kv_encode(v[:, 0])
        k_cache = kv_cache["k"].at[bidx, slot].set(kc)
        v_cache = kv_cache["v"].at[bidx, slot].set(vc)
        k_scale = kv_cache["k_scale"].at[bidx, slot].set(ks)
        v_scale = kv_cache["v_scale"].at[bidx, slot].set(vs)
        out = quantized_decode_attention(
            q, k_cache, k_scale, v_cache, v_scale, lengths=filled)
        new_cache = {"k": k_cache, "v": v_cache, "length": length + 1,
                     "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_cache = kv_cache["k"].at[bidx, slot].set(
            k[:, 0].astype(kv_cache["k"].dtype))
        v_cache = kv_cache["v"].at[bidx, slot].set(
            v[:, 0].astype(kv_cache["v"].dtype))
        out = decode_attention(q, k_cache, v_cache, lengths=filled)
        new_cache = {"k": k_cache, "v": v_cache, "length": length + 1}
    return out, new_cache


def chunk_append_attend(q: Array, k: Array, v: Array, kv_cache: dict,
                        *, n_tokens: Array, window: int):
    """Append up to S new K/V per slot and attend all S chunk queries — the
    chunked-prefill attention core.

    q: (B, S, H, D); k, v: (B, S, KH, D); ``n_tokens``: (B,) int32 — tokens
    0..n-1 of slot b's chunk are real, the rest padding.  A slot with
    n_tokens == 0 keeps its cache slice bit-for-bit unchanged (padding lanes
    write back the values already in their slots).

    Two regimes:
      * window == 0 (append-only cache): scatter the chunk, then one batched
        masked attention over the cache buffer, laid out exactly like
        ``decode_attention`` — bit-identical to S decode ticks, with the
        MXU-friendly (S queries at once) shape.
      * window > 0 (ring buffer): scan token-by-token through the exact
        decode core.  A mid-chunk query may need keys that LATER chunk
        tokens evict from the ring, so post-scatter attention is wrong; the
        scan also preserves decode's buffer layout, keeping bit-identity.
        Only the attention core is sequential — the projections around it
        stay batched.

    Returns (out (B, S, H, D), new_cache).
    """
    b, s = q.shape[:2]
    if window > 0:
        qs = jnp.moveaxis(q, 1, 0)[:, :, None]              # (S, B, 1, H, D)
        ks = jnp.moveaxis(k, 1, 0)[:, :, None]
        vs = jnp.moveaxis(v, 1, 0)[:, :, None]
        valid = jnp.arange(s)[:, None] < n_tokens[None, :]  # (S, B)

        def step(cache, xs):
            q_t, k_t, v_t, ok = xs
            out_t, new_cache = _append_attend_one(q_t, k_t, v_t, cache, window)
            sel = lambda new, old: jnp.where(  # noqa: E731
                ok.reshape((b,) + (1,) * (new.ndim - 1)), new, old)
            return jax.tree.map(sel, new_cache, cache), out_t[:, 0]

        new_cache, outs = jax.lax.scan(step, kv_cache, (qs, ks, vs, valid))
        return jnp.moveaxis(outs, 0, 1), new_cache

    length = kv_cache["length"]                             # (B,)
    s_max = kv_cache["k"].shape[1]
    offs = jnp.arange(s)[None, :]
    valid = offs < n_tokens[:, None]                        # (B, S)
    # Padding lanes collapse onto the slot just past the last real token
    # (the next position a later chunk/tick will overwrite) and write back
    # the value already there — untouched slots stay bit-identical.  When
    # length + n_tokens == S_max that slot does not exist: those lanes go
    # out of bounds and are DROPPED (scatter mode="drop") instead of being
    # clamped onto index S_max - 1, where they would collide with the last
    # real token's write and could silently win the duplicate-index race.
    idx = length[:, None] + jnp.minimum(offs, n_tokens[:, None])
    bidx = jnp.arange(b)[:, None]

    def scatter(buf, new_vals):
        old = buf[bidx, idx]        # OOB reads clamp; those lanes are dropped
        sel = valid.reshape(valid.shape + (1,) * (new_vals.ndim - 2))
        return buf.at[bidx, idx].set(
            jnp.where(sel, new_vals.astype(buf.dtype), old), mode="drop")

    q_pos = length[:, None] + offs                          # (B, S) global
    quantized = "k_scale" in kv_cache
    if quantized:
        kc, ks = _kv_encode(k)                              # (B,S,KH,D)/(B,S,KH)
        vc, vs = _kv_encode(v)
        k_cache = scatter(kv_cache["k"], kc)
        v_cache = scatter(kv_cache["v"], vc)
        k_scale = scatter(kv_cache["k_scale"], ks)
        v_scale = scatter(kv_cache["v_scale"], vs)
        out = quantized_chunk_attention(
            q, k_cache, k_scale, v_cache, v_scale, q_pos=q_pos)
        new_cache = {"k": k_cache, "v": v_cache,
                     "length": length + n_tokens,
                     "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_cache = scatter(kv_cache["k"], k)
        v_cache = scatter(kv_cache["v"], v)
        out = chunk_cache_attention(q, k_cache, v_cache, q_pos=q_pos)
        new_cache = {"k": k_cache, "v": v_cache,
                     "length": length + n_tokens}
    return out, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache: pool + page-table indirection (serving.pages owns the
# host-side allocator; these are the device-side scatter/gather paths).
# ---------------------------------------------------------------------------


def _paged_view(pool: Array, table: Array) -> Array:
    """Gather a dense per-slot cache view out of the page pool.

    pool: (NP, PS, ...); table: (B, MP) int32 physical page per logical
    page (sentinel NP for unallocated entries — the gather clamps, and the
    garbage it reads sits at positions >= the slot's length, masked to
    -1e30 by the attention cores exactly like unpaged out-of-range slots).
    Returns (B, MP*PS, ...)."""
    np_ = pool.shape[0]
    g = pool[jnp.clip(table, 0, np_ - 1)]           # (B, MP, PS, ...)
    b, mp, ps = g.shape[:3]
    return g.reshape((b, mp * ps) + g.shape[3:])


def _paged_scatter(pool: Array, table: Array, pos: Array, vals: Array,
                   valid: Optional[Array] = None) -> Array:
    """Scatter per-lane values into the pool at global cache positions.

    pool: (NP, PS, ...); table: (B, MP); pos: (B, S) global positions;
    vals: (B, S, ...).  Lanes routed to a sentinel table entry (or past the
    table) are DROPPED — dead slots, whose rows are all sentinel, can never
    write into pages a live slot owns.  ``valid`` False lanes write back
    the value already there (a bit-identical no-op), mirroring
    ``chunk_append_attend``'s padding contract."""
    np_, ps = pool.shape[:2]
    mp = table.shape[1]
    page_idx = pos // ps
    off = pos % ps
    page = jnp.take_along_axis(table, jnp.clip(page_idx, 0, mp - 1), axis=1)
    page = jnp.where(page_idx >= mp, np_, page)     # past-table -> drop lane
    vals = vals.astype(pool.dtype)
    if valid is not None:
        old = pool[jnp.clip(page, 0, np_ - 1), off]
        sel = valid.reshape(valid.shape + (1,) * (vals.ndim - 2))
        vals = jnp.where(sel, vals, old)
    return pool.at[page, off].set(vals, mode="drop")


def paged_append_attend(q: Array, k: Array, v: Array, kv_cache: dict,
                        table: Array, *, n_tokens: Optional[Array] = None):
    """Decode / chunked-prefill attention against a PAGED cache.

    kv_cache: {"k_pages": (NP, PS, KH, D), "v_pages": ..., "length": (B,)}
    plus ``k_scale_pages``/``v_scale_pages`` for the quantized cache;
    ``table``: (B, MP) slot→page map.  New K/V are scattered at each slot's
    next positions, then the pool is gathered through the table into a
    dense (B, MP*PS, ...) view feeding the SAME attention cores as the
    unpaged cache.  When MP*PS equals the unpaged ``max_len`` the compute
    graph is identical on identical values, so float-mode decode is
    bit-identical to the unpaged path: garbage in unallocated pages scores
    -1e30 after masking and contributes exact zeros to the softmax, the
    same as unpaged out-of-range slots (tests/test_pages.py).

    q: (B, S, H, D); S == 1 with ``n_tokens`` None is the decode tick, else
    the chunked-prefill append (same padding semantics as
    ``chunk_append_attend``).  Window/ring caches are never paged — the
    engine gates paging to append-only full-attention models.
    """
    b, s = q.shape[:2]
    length = kv_cache["length"]
    decode = s == 1 and n_tokens is None
    if decode:
        pos = length[:, None]
        valid = None
        n_add = jnp.ones((b,), jnp.int32)
    else:
        n = n_tokens if n_tokens is not None else jnp.full((b,), s, jnp.int32)
        offs = jnp.arange(s)[None, :]
        valid = offs < n[:, None]
        pos = length[:, None] + jnp.minimum(offs, n[:, None])
        n_add = n
    q_pos = length[:, None] + jnp.arange(s)[None, :]
    quantized = "k_scale_pages" in kv_cache
    if quantized:
        kc, ks = _kv_encode(k)
        vc, vs = _kv_encode(v)
        kp = _paged_scatter(kv_cache["k_pages"], table, pos, kc, valid)
        vp = _paged_scatter(kv_cache["v_pages"], table, pos, vc, valid)
        ksp = _paged_scatter(kv_cache["k_scale_pages"], table, pos, ks, valid)
        vsp = _paged_scatter(kv_cache["v_scale_pages"], table, pos, vs, valid)
        new_cache = {"k_pages": kp, "v_pages": vp, "k_scale_pages": ksp,
                     "v_scale_pages": vsp, "length": length + n_add}
        if decode:
            out = quantized_decode_attention(
                q, _paged_view(kp, table), _paged_view(ksp, table),
                _paged_view(vp, table), _paged_view(vsp, table),
                lengths=length + 1)
        else:
            out = quantized_chunk_attention(
                q, _paged_view(kp, table), _paged_view(ksp, table),
                _paged_view(vp, table), _paged_view(vsp, table),
                q_pos=q_pos)
    else:
        kp = _paged_scatter(kv_cache["k_pages"], table, pos, k, valid)
        vp = _paged_scatter(kv_cache["v_pages"], table, pos, v, valid)
        new_cache = {"k_pages": kp, "v_pages": vp, "length": length + n_add}
        if decode:
            out = decode_attention(q, _paged_view(kp, table),
                                   _paged_view(vp, table), lengths=length + 1)
        else:
            out = chunk_cache_attention(q, _paged_view(kp, table),
                                        _paged_view(vp, table), q_pos=q_pos)
    return out, new_cache


# ---------------------------------------------------------------------------
# Attention block (projections through Numerics)
# ---------------------------------------------------------------------------


def init_attention(key, mcfg, layer_shape=()) -> dict:
    d, h, kh = mcfg.d_model, mcfg.num_heads, mcfg.num_kv_heads
    hd = mcfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    shape = lambda *s: layer_shape + s  # noqa: E731
    init = lambda k, *s: (  # noqa: E731
        jax.random.normal(k, shape(*s)) * std).astype(mcfg.param_dtype)
    return {
        "wq": init(k1, d, h * hd),
        "wk": init(k2, d, kh * hd),
        "wv": init(k3, d, kh * hd),
        "wo": init(k4, h * hd, d),
    }


def _fused_decode_attention_block(params, x, mcfg, nx, *, positions,
                                  kv_cache):
    """One fused-kernel decode tick of ``attention_block``.

    Replaces the packed chain's three ``nx.dense`` projection dispatches
    with ONE ``fused_qkv_packed_pallas`` launch and the jnp quantized-KV
    attention with the ``fused_quantized_decode_attention`` Pallas kernel
    (``kernels.abfp_decode_fused``) — bit-identical to the chain by
    construction (tests/test_fused.py, tests/test_sharded_serving.py).

    PRNG contract: the packed chain folds ``(base key, call counter)`` per
    ``Numerics.dense`` call; the fused launch consumes the SAME three
    (key, counter) pairs for wq/wk/wv — one per weight segment — and bumps
    the counter identically, so the wo projection (and every later layer)
    sees an unchanged stream.
    """
    from repro.kernels.abfp_decode_fused import (
        fused_qkv_dense,
        fused_quantized_decode_attention,
    )

    b, s, _ = x.shape
    h, kh, hd = mcfg.num_heads, mcfg.num_kv_heads, mcfg.resolved_head_dim

    keys = []
    for _ in range(3):                       # wq, wk, wv — in chain order
        key = None
        if nx._key is not None and nx.quant.noise_lsb > 0.0:
            key = jax.random.fold_in(nx._key, nx._count)
        nx._count += 1
        keys.append(key)
    yq, yk, yv = fused_qkv_dense(
        x, (params["wq"], params["wk"], params["wv"]), nx.quant, keys,
        nx.mesh)
    q = yq.reshape(b, s, h, hd)
    k = yk.reshape(b, s, kh, hd)
    v = yv.reshape(b, s, kh, hd)
    if mcfg.pos_type == "rope":
        q = rope(q, positions, mcfg.rope_theta, mcfg.rope_fraction)
        k = rope(k, positions, mcfg.rope_theta, mcfg.rope_fraction)

    # ``_append_attend_one``'s quantized branch (window == 0: slot ==
    # length), with the attention einsum chain swapped for the Pallas
    # kernel.  Under a mesh the jnp form runs instead: it is bit-identical
    # to the kernel (enforced by test) and partitions under GSPMD, which a
    # pallas_call does not.
    length = kv_cache["length"]
    bidx = jnp.arange(b)
    kc, ks = _kv_encode(k[:, 0])
    vc, vs = _kv_encode(v[:, 0])
    k_cache = kv_cache["k"].at[bidx, length].set(kc)
    v_cache = kv_cache["v"].at[bidx, length].set(vc)
    k_scale = kv_cache["k_scale"].at[bidx, length].set(ks)
    v_scale = kv_cache["v_scale"].at[bidx, length].set(vs)
    if nx.mesh is None:
        out = fused_quantized_decode_attention(
            q, k_cache, k_scale, v_cache, v_scale, lengths=length + 1)
    else:
        out = quantized_decode_attention(
            q, k_cache, k_scale, v_cache, v_scale, lengths=length + 1)
    new_cache = {"k": k_cache, "v": v_cache, "length": length + 1,
                 "k_scale": k_scale, "v_scale": v_scale}
    return nx.dense(out.reshape(b, s, h * hd), params["wo"]), new_cache


def _use_fused_decode(params, nx, s, kv_cache, cross_kv, window, n_tokens):
    """Does this ``attention_block`` call hit the fused decode fast path?

    Fused mode + a single-token decode tick on an (unpaged, un-windowed)
    quantized KV cache with all three projection weights packed.  Anything
    else — prefill chunks, float/paged/windowed caches, unpacked weights —
    falls back to the packed chain, which computes the same numbers
    dispatch-by-dispatch (gains included, via ``dense_packed``).
    """
    return (nx.quant.mode == "abfp_fused"
            and s == 1 and n_tokens is None and window == 0
            and kv_cache is not None and cross_kv is None
            and "k_pages" not in kv_cache and "k_scale" in kv_cache
            and all(isinstance(params[w], PackedWeight)
                    for w in ("wq", "wk", "wv")))


def attention_block(
    params: dict,
    x: Array,
    mcfg,
    nx: "Numerics",
    *,
    positions: Array,
    causal: bool = True,
    window: int = 0,
    kv_cache: Optional[dict] = None,
    cross_kv: Optional[tuple] = None,
    train_mode: bool = False,
    n_tokens: Optional[Array] = None,
    page_table: Optional[Array] = None,
):
    """Self- (or cross-) attention with optional KV cache for decode.

    Returns (output, new_kv_cache).  ``kv_cache``: {"k": (B,S,KH,D),
    "v": ..., "length": (B,)} — ring buffer when window > 0.
    ``train_mode`` selects the q-chunked remat attention (backward-memory
    bounded); inference uses the kv-chunked online-softmax path.

    With a cache and S > 1 (or ``n_tokens`` given) this is the chunked
    prefill path: x holds a prompt chunk, ``n_tokens`` (B,) marks how many
    of its S tokens are real per slot (None == all S), and the whole chunk
    is appended + attended in one pass (``chunk_append_attend``).

    A PAGED cache ({"k_pages": ..., ...}, see serving.pages) requires
    ``page_table`` (B, MP) and routes through ``paged_append_attend``.
    """
    b, s, _ = x.shape
    h, kh, hd = mcfg.num_heads, mcfg.num_kv_heads, mcfg.resolved_head_dim

    if _use_fused_decode(params, nx, s, kv_cache, cross_kv, window,
                         n_tokens):
        # abfp_fused decode tick: one fused QKV launch + Pallas quantized
        # attention, bit-identical to the chain below at matching gains.
        return _fused_decode_attention_block(
            params, x, mcfg, nx, positions=positions, kv_cache=kv_cache)

    q = nx.dense(x, params["wq"]).reshape(b, s, h, hd)
    if cross_kv is None:
        k = nx.dense(x, params["wk"]).reshape(b, s, kh, hd)
        v = nx.dense(x, params["wv"]).reshape(b, s, kh, hd)
        if mcfg.pos_type == "rope":
            q = rope(q, positions, mcfg.rope_theta, mcfg.rope_fraction)
            k = rope(k, positions, mcfg.rope_theta, mcfg.rope_fraction)
    else:
        k, v = cross_kv

    new_cache = None
    if kv_cache is not None and cross_kv is None and "k_pages" in kv_cache:
        assert page_table is not None, "paged kv_cache needs a page_table"
        out, new_cache = paged_append_attend(q, k, v, kv_cache, page_table,
                                             n_tokens=n_tokens)
    elif kv_cache is not None and cross_kv is None:
        if s == 1 and n_tokens is None:
            # Decode: append this step's K/V, attend over the filled cache.
            out, new_cache = _append_attend_one(q, k, v, kv_cache, window)
        else:
            # Chunked prefill: append + attend a whole prompt chunk.
            n = (n_tokens if n_tokens is not None
                 else jnp.full((b,), s, jnp.int32))
            out, new_cache = chunk_append_attend(
                q, k, v, kv_cache, n_tokens=n, window=window)
    elif cross_kv is not None:
        if train_mode:
            out = train_attention(q, k, v, causal=False,
                                  q_chunk=mcfg.attn_chunk)
        elif mcfg.use_flash_attention:
            from repro.kernels.flash_attention import flash_attention
            out = flash_attention(q, k, v, causal=False)
        else:
            out = chunked_attention(q, k, v, causal=False,
                                    chunk=mcfg.attn_chunk)
    elif train_mode:
        out = train_attention(q, k, v, causal=causal, window=window,
                              q_chunk=mcfg.attn_chunk)
    elif mcfg.use_flash_attention:
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = chunked_attention(
            q, k, v, causal=causal, window=window,
            q_offset=0, chunk=mcfg.attn_chunk)

    out = out.reshape(b, s, h * hd)
    return nx.dense(out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLP (swiglu / geglu / gelu)
# ---------------------------------------------------------------------------


def init_mlp(key, mcfg, layer_shape=()) -> dict:
    d, f = mcfg.d_model, mcfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    shape = lambda *s: layer_shape + s  # noqa: E731
    p = {
        "wi": (jax.random.normal(k1, shape(d, f)) * d**-0.5).astype(mcfg.param_dtype),
        "wo": (jax.random.normal(k2, shape(f, d)) * f**-0.5).astype(mcfg.param_dtype),
    }
    if mcfg.mlp_type in ("swiglu", "geglu"):
        p["wg"] = (jax.random.normal(k3, shape(d, f))
                   * d**-0.5).astype(mcfg.param_dtype)
    return p


def mlp_block(params: dict, x: Array, mcfg, nx: "Numerics") -> Array:
    h = nx.dense(x, params["wi"])
    if mcfg.mlp_type == "swiglu":
        g = nx.dense(x, params["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    elif mcfg.mlp_type == "geglu":
        g = nx.dense(x, params["wg"])
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return nx.dense(h, params["wo"])


# ---------------------------------------------------------------------------
# im2col (utility: how the paper maps convs onto tiled matmuls, Sec. V)
# ---------------------------------------------------------------------------


def im2col(x: Array, kh: int, kw: int, stride: int = 1) -> Array:
    """(B, H, W, C) -> (B, H', W', kh*kw*C) patches so a conv becomes a
    matmul that ABFP can tile — the paper's treatment of ResNet50 convs."""
    b, hh, ww, c = x.shape
    oh = (hh - kh) // stride + 1
    ow = (ww - kw) // stride + 1
    idx_h = (jnp.arange(oh) * stride)[:, None] + jnp.arange(kh)[None, :]
    idx_w = (jnp.arange(ow) * stride)[:, None] + jnp.arange(kw)[None, :]
    patches = x[:, idx_h[:, None, :, None], idx_w[None, :, None, :], :]
    return patches.reshape(b, oh, ow, kh * kw * c)
