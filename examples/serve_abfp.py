"""Serve a small model with batched requests, comparing FLOAT32 serving
against ABFP-simulated serving (the AMS deployment scenario).

Run:  PYTHONPATH=src python examples/serve_abfp.py
"""

import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.abfp import QuantConfig
from repro.models import init_params
from repro.serving import Request, ServingEngine


def serve(params, mcfg, quant, label):
    eng = ServingEngine(params, mcfg, capacity=4, max_len=64, quant=quant)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(1, mcfg.vocab_size, 4).tolist(),
                    max_new_tokens=6) for i in range(8)]
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = {r.uid: r.generated for r in done}
    print(f"[{label}] {len(done)} requests in {dt:.1f}s ({eng.ticks} ticks)")
    return toks


def main():
    mcfg = smoke_config("tinyllama-1.1b")
    params = init_params(jax.random.PRNGKey(0), mcfg)

    float_out = serve(params, mcfg, QuantConfig(mode="float"), "float32")
    abfp_out = serve(
        params, mcfg,
        QuantConfig(mode="abfp_ref", tile_width=8, gain=1.0, noise_lsb=0.5),
        "abfp t8/g1")
    # Production path: weights quantized once at engine init (int8 codes +
    # bf16 scales), every tick runs the packed Pallas kernel.
    packed_out = serve(
        params, mcfg,
        QuantConfig(mode="abfp_packed", tile_width=8, gain=1.0, noise_lsb=0.5),
        "abfp-packed t8/g1")

    agree = sum(float_out[u] == abfp_out[u] for u in float_out)
    print(f"\ngreedy outputs identical for {agree}/{len(float_out)} requests "
          f"at tile 8 / gain 1 (the paper's <1%-loss configuration)")
    agree_p = sum(float_out[u] == packed_out[u] for u in float_out)
    print(f"packed serving agrees with float for {agree_p}/{len(float_out)}")
    for u in list(float_out)[:3]:
        print(f"  req {u}: float={float_out[u]}  abfp={abfp_out[u]}  "
              f"packed={packed_out[u]}")


if __name__ == "__main__":
    main()
