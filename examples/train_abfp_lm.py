"""End-to-end driver (deliverable b): train a ~100M-class LM for a few
hundred steps on the synthetic pipeline, with checkpointing and resume.

By default trains the reduced config for CPU speed; pass --full-360m to train
the real smollm-360m config (same code path, much slower on CPU).

Run:  PYTHONPATH=src python examples/train_abfp_lm.py
      PYTHONPATH=src python examples/train_abfp_lm.py --qat   # ABFP forward
"""

import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qat", action="store_true",
                    help="QAT: ABFP-simulated forward + STE backward")
    ap.add_argument("--full-360m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-360m",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "128",
        "--ckpt-dir", "/tmp/abfp_lm_run",
        "--ckpt-every", "100",
        "--resume", "auto",
        "--quant", "qat" if args.qat else "float",
    ]
    if not args.full_360m:
        cmd.append("--reduced")
    print("+", " ".join(cmd))
    sys.exit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
