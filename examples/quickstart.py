"""Quickstart: the ABFP number format in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.abfp import QuantConfig, abfp_matmul
from repro.core.energy import paper_section6_comparison
from repro.kernels.abfp_matmul import abfp_matmul_pallas


def main():
    key = jax.random.PRNGKey(0)
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.normal(kx, (16, 768), jnp.float32)
    w = jax.random.laplace(kw, (768, 256)) * 0.04
    y_exact = x @ w

    print("ABFP error vs tile width and gain (8/8/8 bits, 0.5 LSB ADC noise)")
    print(f"{'tile':>5} {'gain':>5} {'rms error':>12}")
    for tile in (8, 32, 128):
        for gain in (1.0, 8.0):
            cfg = QuantConfig(mode="abfp_ref", tile_width=tile, gain=gain,
                              noise_lsb=0.5, out_dtype=jnp.float32)
            y = abfp_matmul(x, w, cfg, kn)
            rms = float(jnp.sqrt(jnp.mean((y - y_exact) ** 2)))
            print(f"{tile:>5} {gain:>5.0f} {rms:>12.5f}")
    print("-> small tiles want gain 1; large tiles need gain to recover "
          "the LSBs the ADC drops (paper Sec. III-B).")

    # The fused Pallas kernel computes the same thing (interpret mode on CPU).
    cfg = QuantConfig(tile_width=128, gain=8.0, noise_lsb=0.0,
                      out_dtype=jnp.float32)
    y_ker = abfp_matmul_pallas(x, w, cfg)
    y_ref = abfp_matmul(x, w, cfg)
    print(f"\nPallas kernel max |diff| vs reference: "
          f"{float(jnp.abs(y_ker - y_ref).max()):.2e}")

    cmp = paper_section6_comparison()
    print(f"\nSec. VI energy analysis: {cmp['adc_energy_reduction']:.2f}x "
          f"less ADC energy and {cmp['macs_per_cycle_gain']:.0f}x more "
          f"MACs/cycle than Rekhi et al.'s design point.")


if __name__ == "__main__":
    main()
