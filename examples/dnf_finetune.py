"""DNF in action (paper Sec. IV-B): degrade a trained model with a harsh
ABFP config, capture per-layer differential-noise histograms once, finetune
with sampled noise, and compare against QAT.

Run:  PYTHONPATH=src:. python examples/dnf_finetune.py
"""

import sys

sys.path.insert(0, ".")

from benchmarks.bench_finetune import run  # noqa: E402


def main():
    rows = []
    out = run(rows)
    print("\n".join(rows))
    print(f"\nFLOAT32 accuracy          : {out['float']:.4f}")
    print(f"degraded (ABFP harsh)     : {out['degraded']:.4f}")
    print(f"after QAT                 : {out['qat']:.4f} "
          f"({out['qat_s']*1e3:.0f} ms/step)")
    print(f"after DNF                 : {out['dnf']:.4f} "
          f"({out['dnf_s']*1e3:.0f} ms/step)")
    print(f"DNF speedup over QAT      : {out['speedup']:.2f}x "
          f"(paper reports ~4x on A100)")
    print(f"layer-wise noise std (Fig. 5 analysis): "
          f"{[round(s, 4) for s in out['layer_stds']]}")


if __name__ == "__main__":
    main()
